//! [`StashCodec`]: the encode/decode contract of the stash, implemented by
//! adapters over the existing compression stacks:
//!
//! * [`GeckoStashCodec`] — component-stream layout: Gecko-encoded exponents
//!   (payload + width metadata), a packed `n`-bit mantissa stream, and an
//!   optional sign stream.  Bit-for-bit the accounting the analytic
//!   [`FootprintModel`](crate::report::FootprintModel) charges, so stash
//!   ledger totals and `report::footprint` agree exactly.
//! * [`SfpStashCodec`] — the §V hardware layout via [`SfpCodec`]: one
//!   interleaved payload stream plus row-width metadata, as the 8-lane
//!   compressor would burst it to DRAM.  A `FixedBias` exponent mode in
//!   the [`ContainerMeta`] (Quantum Exponent's learned per-layer bias)
//!   switches the layout to per-row bias registers, so the policy's
//!   exponent narrowing reaches the hardware stream too.
//! * [`RawStashCodec`] — the FP32/BF16 baseline: container words verbatim.
//! * [`JsStashCodec`] — the §VI-B JS zero-skip baseline on real bytes: one
//!   tag bit per value, container words only for the non-zeros (exactly
//!   the [`crate::baselines::js_bits`] accounting) — the real-byte leg of
//!   the Fig. 13 combined variants.
//!
//! Decoding is zero-copy: [`StashCodec::decode_view`] consumes
//! [`SegReader`]s over arena-resident chunk runs in place; the owned
//! [`StashCodec::decode`] is a thin wrapper over single-segment readers.
//!
//! Every codec is *lossless after quantization*: `decode(encode(v, meta))`
//! equals `quantize(v, meta.mant(), meta.container)` bit-for-bit (property
//! tested in `rust/tests/props.rs`, down to the 1-mantissa-bit extreme).

use crate::formats::layout::{block_fields, block_value};
use crate::formats::{bf16_bits, exponent, Container, ExponentLayout, F32_MANT_BITS};
use crate::gecko::{self, BitWriter, Kernel, Mode, SegReader};
use crate::sfp::SfpCodec;
use crate::stats::ComponentBits;

/// Per-tensor container metadata chosen by the active policy (QM/BitChop):
/// which container the tensor is stashed in and how many mantissa bits
/// survive, plus the exponent layout and sign handling.
#[derive(Debug, Clone, Copy)]
pub struct ContainerMeta {
    pub container: Container,
    /// Mantissa bits to keep (clamped to the container's mantissa length).
    pub mant_bits: u32,
    /// Exponent shape: per-value learned width (lossless Gecko storage),
    /// AdaptivFloat per-tensor bias window, or Flexpoint block-shared.
    pub layout: ExponentLayout,
    /// Elide value signs — only valid for known-non-negative tensors
    /// (post-ReLU activations, §IV-D).
    pub elide_sign: bool,
}

impl ContainerMeta {
    pub fn new(container: Container, mant_bits: u32) -> Self {
        Self {
            container,
            mant_bits,
            layout: ExponentLayout::default(),
            elide_sign: false,
        }
    }

    pub fn with_sign_elision(mut self, elide: bool) -> Self {
        self.elide_sign = elide;
        self
    }

    /// Set the Gecko storage mode of a per-value-width exponent stream
    /// (the historical `exp_mode` knob, kept for the Width layout).
    pub fn with_exp_mode(mut self, mode: Mode) -> Self {
        let bits = match self.layout {
            ExponentLayout::Width { bits, .. } => bits,
            _ => crate::formats::EXP_BITS,
        };
        self.layout = ExponentLayout::Width { bits, mode };
        self
    }

    pub fn with_layout(mut self, layout: ExponentLayout) -> Self {
        self.layout = layout;
        self
    }

    /// Gecko storage mode of the per-value exponent stream (`Delta` for
    /// the non-Width layouts, which do not use the adaptive Gecko path).
    pub fn exp_mode(&self) -> Mode {
        self.layout.gecko_mode()
    }

    /// Effective mantissa length inside this container.
    pub fn mant(&self) -> u32 {
        self.mant_bits.min(self.container.mant_bits())
    }

    /// The container value every stored f32 is reduced to, for layouts
    /// whose quantizer is per-value; panics for `BlockShared` (whose
    /// quantizer needs the whole slice — use
    /// [`ContainerMeta::quantized_slice`]).
    pub fn quantized(&self, v: f32) -> f32 {
        self.layout.quantize_value(v, self.mant(), self.container)
    }

    /// Quantize a whole tensor under this meta — the fixed point every
    /// codec's `decode(encode(vals))` equals bit-for-bit.
    pub fn quantized_slice(&self, vals: &[f32]) -> Vec<f32> {
        self.layout.quantize_slice(vals, self.mant(), self.container)
    }
}

/// One encoded tensor as raw bit streams (not yet placed in the arena).
#[derive(Debug, Clone)]
pub struct EncodedStreams {
    pub count: usize,
    /// `(words, len_bits)` per stream, in codec-defined order.
    pub streams: Vec<(Vec<u64>, usize)>,
    /// Exact component split of the stored bits (the ledger's Fig. 12 axis;
    /// `bits.total()` equals the summed stream lengths).
    pub bits: ComponentBits,
}

impl EncodedStreams {
    pub fn total_bits(&self) -> usize {
        self.streams.iter().map(|s| s.1).sum()
    }

    /// Concatenate chunk encodings stream-by-stream (bit-granular append —
    /// the `gecko::bitstream` chunk-boundary path).  Chunks must come from
    /// the same codec/meta, with whole codec groups everywhere but the
    /// last chunk; [`StashCodec::encode_chunked`] guarantees both.
    pub fn concat(chunks: &[EncodedStreams]) -> Option<EncodedStreams> {
        let first = chunks.first()?;
        let mut writers: Vec<BitWriter> = first
            .streams
            .iter()
            .map(|(w, b)| BitWriter::from_words(w.clone(), *b))
            .collect();
        let mut count = first.count;
        let mut bits = first.bits;
        for c in &chunks[1..] {
            debug_assert_eq!(c.streams.len(), writers.len());
            for (w, (words, len)) in writers.iter_mut().zip(&c.streams) {
                w.append_words(words, *len);
            }
            count += c.count;
            bits.add(c.bits);
        }
        Some(EncodedStreams {
            count,
            streams: writers.into_iter().map(BitWriter::into_words).collect(),
            bits,
        })
    }
}

/// The stash's pluggable compression contract.
pub trait StashCodec: Send + Sync {
    /// Short identifier for CLI/ledger rows.
    fn name(&self) -> &'static str;

    /// Group granularity under `meta`: chunked encoding is bit-identical
    /// to one-shot only when every chunk but the last is a multiple of
    /// this many values (the codec pads partial groups, so an unaligned
    /// interior chunk would bake padding into the middle of the stream).
    fn group(&self, meta: &ContainerMeta) -> usize;

    /// Encode `vals` under `meta` with an explicit [`Kernel`] — `Word` is
    /// the word-parallel production path, `Scalar` the per-value reference.
    /// Both emit bit-identical streams (differential-tested), so content
    /// hashes and cache fingerprints never depend on the kernel.
    fn encode_kernel(&self, vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams;

    /// [`StashCodec::decode_view`] with an explicit kernel.
    fn decode_view_kernel(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32>;

    /// Encode `vals` under `meta` (with the process-wide active kernel).
    fn encode(&self, vals: &[f32], meta: &ContainerMeta) -> EncodedStreams {
        self.encode_kernel(vals, meta, Kernel::active())
    }

    /// Decode a tensor from per-stream bit readers (codec-defined stream
    /// order, matching [`EncodedStreams::streams`]) — the zero-copy
    /// restore path: the readers borrow arena chunk memory directly, so
    /// no materialized `Vec<u64>` copies exist on the restore path.
    fn decode_view(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
    ) -> Vec<f32> {
        self.decode_view_kernel(count, streams, meta, Kernel::active())
    }

    /// Decode a materialized tensor encoded with the same `meta`
    /// (convenience over [`StashCodec::decode_view`] for one-shot paths,
    /// tests, and benches).
    fn decode(&self, enc: &EncodedStreams, meta: &ContainerMeta) -> Vec<f32> {
        self.decode_kernel(enc, meta, Kernel::active())
    }

    /// [`StashCodec::decode`] with an explicit kernel.
    fn decode_kernel(
        &self,
        enc: &EncodedStreams,
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32> {
        let mut readers: Vec<SegReader> = enc
            .streams
            .iter()
            .map(|(words, bits)| SegReader::single(words, *bits))
            .collect();
        self.decode_view_kernel(enc.count, &mut readers, meta, kernel)
    }

    /// Encode in `chunk_values`-sized pieces (rounded up to a group
    /// multiple) and concatenate — bit-identical to one-shot [`encode`]
    /// (`StashCodec::encode`), but bounds the working set per piece and is
    /// how pool workers stream large tensors through.
    fn encode_chunked(
        &self,
        vals: &[f32],
        meta: &ContainerMeta,
        chunk_values: usize,
    ) -> EncodedStreams {
        self.encode_chunked_kernel(vals, meta, chunk_values, Kernel::active())
    }

    /// [`StashCodec::encode_chunked`] with an explicit kernel.
    fn encode_chunked_kernel(
        &self,
        vals: &[f32],
        meta: &ContainerMeta,
        chunk_values: usize,
        kernel: Kernel,
    ) -> EncodedStreams {
        let g = self.group(meta).max(1);
        let chunk = chunk_values.max(1).div_ceil(g) * g;
        let parts: Vec<EncodedStreams> = vals
            .chunks(chunk)
            .map(|c| self.encode_kernel(c, meta, kernel))
            .collect();
        EncodedStreams::concat(&parts).unwrap_or_else(|| self.encode_kernel(vals, meta, kernel))
    }
}

/// Gecko-exponent + packed-mantissa + sign component streams.
#[derive(Debug, Default, Clone, Copy)]
pub struct GeckoStashCodec;

impl StashCodec for GeckoStashCodec {
    fn name(&self) -> &'static str {
        "gecko"
    }

    fn group(&self, meta: &ContainerMeta) -> usize {
        match meta.layout {
            ExponentLayout::BlockShared { block, .. } => block.max(1),
            // fixed-width per-value fields: any chunk partition is
            // bit-identical, no group padding
            ExponentLayout::Bias { .. } => 1,
            ExponentLayout::Width { mode: Mode::Delta, .. } => gecko::GROUP,
            ExponentLayout::Width {
                mode: Mode::FixedBias { group, .. },
                ..
            } => group,
        }
    }

    fn encode_kernel(&self, vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
        match meta.layout {
            ExponentLayout::Bias { .. } => return encode_bias_streams(vals, meta, kernel),
            ExponentLayout::BlockShared { .. } => return encode_block_streams(vals, meta, kernel),
            ExponentLayout::Width { .. } => {}
        }
        let n = meta.mant();
        let exps = gecko::exponents(vals);
        let enc = gecko::encode_kernel(&exps, meta.exp_mode(), kernel);
        let mut mant = BitWriter::with_capacity(vals.len() * n as usize);
        let mut sign = BitWriter::with_capacity(if meta.elide_sign { 0 } else { vals.len() });
        match kernel {
            Kernel::Word => {
                // Bit-plane packing: the mantissa plane streams 64 fields
                // per `pack_lanes` call; the sign plane collapses to one
                // 64-bit splice per chunk (first value's sign at the MSB,
                // exactly the scalar push order).
                let mut fields = [0u64; 64];
                for chunk in vals.chunks(64) {
                    if n > 0 {
                        for (f, &v) in fields.iter_mut().zip(chunk) {
                            *f = ((v.to_bits() >> (F32_MANT_BITS - n)) & ((1u32 << n) - 1)) as u64;
                        }
                        mant.pack_lanes(&fields[..chunk.len()], n);
                    }
                    if !meta.elide_sign {
                        let mut w = 0u64;
                        for &v in chunk {
                            w = (w << 1) | (v.to_bits() >> 31) as u64;
                        }
                        sign.push_word(w, chunk.len() as u32);
                    }
                }
            }
            Kernel::Scalar => {
                for &v in vals {
                    let b = v.to_bits();
                    if n > 0 {
                        mant.push(((b >> (F32_MANT_BITS - n)) & ((1u32 << n) - 1)) as u64, n);
                    }
                    if !meta.elide_sign {
                        sign.push((b >> 31) as u64, 1);
                    }
                }
            }
        }
        let (mw, mb) = mant.into_words();
        let (sw, sb) = sign.into_words();
        let bits = ComponentBits {
            sign: sb as f64,
            exponent: enc.payload_bits as f64,
            mantissa: mb as f64,
            metadata: enc.metadata_bits as f64,
        };
        EncodedStreams {
            count: vals.len(),
            streams: vec![
                (enc.payload, enc.payload_bits),
                (enc.metadata, enc.metadata_bits),
                (mw, mb),
                (sw, sb),
            ],
            bits,
        }
    }

    fn decode_view_kernel(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32> {
        match meta.layout {
            ExponentLayout::Bias { .. } => return decode_bias_streams(count, streams, meta, kernel),
            ExponentLayout::BlockShared { .. } => {
                return decode_block_streams(count, streams, meta, kernel)
            }
            ExponentLayout::Width { .. } => {}
        }
        let n = meta.mant();
        let [payload, metadata, mant, sign] = streams else {
            panic!("gecko codec expects 4 streams");
        };
        let exps = gecko::decode_readers_kernel(payload, metadata, count, meta.exp_mode(), kernel);
        match kernel {
            Kernel::Word => {
                let mut out = Vec::with_capacity(count);
                let mut mants = [0u64; 64];
                for chunk in exps.chunks(64) {
                    let l = chunk.len();
                    if n > 0 {
                        mant.unpack_lanes(n, &mut mants[..l]);
                    }
                    let sw = if meta.elide_sign { 0 } else { sign.read_word(l as u32) };
                    for (c, &e) in chunk.iter().enumerate() {
                        let m = if n > 0 {
                            (mants[c] as u32) << (F32_MANT_BITS - n)
                        } else {
                            0
                        };
                        let s = if meta.elide_sign {
                            0
                        } else {
                            ((sw >> (l - 1 - c)) & 1) as u32
                        };
                        out.push(f32::from_bits((s << 31) | ((e as u32) << 23) | m));
                    }
                }
                out
            }
            Kernel::Scalar => exps
                .iter()
                .map(|&e| {
                    let m = if n > 0 {
                        (mant.read(n) as u32) << (F32_MANT_BITS - n)
                    } else {
                        0
                    };
                    let s = if meta.elide_sign {
                        0
                    } else {
                        sign.read(1) as u32
                    };
                    f32::from_bits((s << 31) | ((e as u32) << 23) | m)
                })
                .collect(),
        }
    }
}

/// AdaptivFloat component streams: a fixed `layout.field_bits()`-wide
/// exponent field per value (0 = zero, else `e - lo + 1` within the bias
/// window — exactly the bits `ContainerPlan::bits_per_value` charges), a
/// packed `n`-bit mantissa stream, and signs.  Stream order mirrors the
/// Gecko layout (`[exponent, metadata, mantissa, sign]`) with an empty
/// metadata stream: the field width is fixed, nothing adapts per group.
fn encode_bias_streams(vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
    let n = meta.mant();
    let b = meta.layout.field_bits();
    let (lo, _) = meta.layout.bias_window().expect("bias layout");
    let field_of = |q: f32| -> u64 {
        let e = exponent(q) as i32;
        if e == 0 {
            0
        } else {
            (e - lo + 1) as u64
        }
    };
    let mant_of = |q: f32| -> u64 {
        if n == 0 {
            0
        } else {
            ((q.to_bits() >> (F32_MANT_BITS - n)) & ((1u32 << n) - 1)) as u64
        }
    };
    let mut exp = BitWriter::with_capacity(vals.len() * b as usize);
    let mut mant = BitWriter::with_capacity(vals.len() * n as usize);
    let mut sign = BitWriter::with_capacity(if meta.elide_sign { 0 } else { vals.len() });
    match kernel {
        Kernel::Word => {
            let mut ef = [0u64; 64];
            let mut mf = [0u64; 64];
            for chunk in vals.chunks(64) {
                let mut sw = 0u64;
                for (c, &v) in chunk.iter().enumerate() {
                    let q = meta.quantized(v);
                    ef[c] = field_of(q);
                    mf[c] = mant_of(q);
                    sw = (sw << 1) | (q.to_bits() >> 31) as u64;
                }
                exp.pack_lanes(&ef[..chunk.len()], b);
                if n > 0 {
                    mant.pack_lanes(&mf[..chunk.len()], n);
                }
                if !meta.elide_sign {
                    sign.push_word(sw, chunk.len() as u32);
                }
            }
        }
        Kernel::Scalar => {
            for &v in vals {
                let q = meta.quantized(v);
                exp.push(field_of(q), b);
                if n > 0 {
                    mant.push(mant_of(q), n);
                }
                if !meta.elide_sign {
                    sign.push((q.to_bits() >> 31) as u64, 1);
                }
            }
        }
    }
    let (ew, eb) = exp.into_words();
    let (mw, mb) = mant.into_words();
    let (sw, sb) = sign.into_words();
    let bits = ComponentBits {
        sign: sb as f64,
        exponent: eb as f64,
        mantissa: mb as f64,
        metadata: 0.0,
    };
    EncodedStreams {
        count: vals.len(),
        streams: vec![(ew, eb), (Vec::new(), 0), (mw, mb), (sw, sb)],
        bits,
    }
}

fn decode_bias_streams(
    count: usize,
    streams: &mut [SegReader<'_>],
    meta: &ContainerMeta,
    kernel: Kernel,
) -> Vec<f32> {
    let [exp, _metadata, mant, sign] = streams else {
        panic!("bias layout expects 4 streams");
    };
    let n = meta.mant();
    let b = meta.layout.field_bits();
    let (lo, _) = meta.layout.bias_window().expect("bias layout");
    let value_of = |f: u64, m: u64, s: u32| -> f32 {
        let e = if f == 0 { 0 } else { (f as i32 + lo - 1) as u32 };
        let m = if n == 0 {
            0
        } else {
            (m as u32) << (F32_MANT_BITS - n)
        };
        f32::from_bits((s << 31) | (e << 23) | m)
    };
    match kernel {
        Kernel::Word => {
            let mut out = Vec::with_capacity(count);
            let mut ef = [0u64; 64];
            let mut mf = [0u64; 64];
            let mut rem = count;
            while rem > 0 {
                let l = rem.min(64);
                exp.unpack_lanes(b, &mut ef[..l]);
                if n > 0 {
                    mant.unpack_lanes(n, &mut mf[..l]);
                }
                let sw = if meta.elide_sign { 0 } else { sign.read_word(l as u32) };
                for c in 0..l {
                    let s = if meta.elide_sign {
                        0
                    } else {
                        ((sw >> (l - 1 - c)) & 1) as u32
                    };
                    let m = if n > 0 { mf[c] } else { 0 };
                    out.push(value_of(ef[c], m, s));
                }
                rem -= l;
            }
            out
        }
        Kernel::Scalar => (0..count)
            .map(|_| {
                let f = exp.read(b);
                let m = if n > 0 { mant.read(n) } else { 0 };
                let s = if meta.elide_sign { 0 } else { sign.read(1) as u32 };
                value_of(f, m, s)
            })
            .collect(),
    }
}

/// Flexpoint component streams: one `field_bits()`-wide shared exponent
/// per block, a packed `n + 1`-bit explicit-leading-one significand per
/// value, and signs — stream order mirrors the Gecko layout with an
/// empty metadata stream.  Used by both the gecko and sfp adapters (the
/// block layout has no per-value exponents for either stack to
/// compress, so their streams coincide).
fn encode_block_streams(vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
    let n = meta.mant();
    let block = meta.layout.block().expect("block layout");
    let eb = meta.layout.field_bits();
    let w = n + 1;
    let (emaxs, fields) = block_fields(vals, n, meta.container, block, eb);
    let mut exp = BitWriter::with_capacity(emaxs.len() * eb as usize);
    let mut mant = BitWriter::with_capacity(vals.len() * w as usize);
    let mut sign = BitWriter::with_capacity(if meta.elide_sign { 0 } else { vals.len() });
    match kernel {
        Kernel::Word => {
            let mut buf = [0u64; 64];
            for chunk in emaxs.chunks(64) {
                for (f, &e) in buf.iter_mut().zip(chunk) {
                    *f = e as u64;
                }
                exp.pack_lanes(&buf[..chunk.len()], eb);
            }
            for chunk in fields.chunks(64) {
                for (f, &m) in buf.iter_mut().zip(chunk) {
                    *f = m as u64;
                }
                mant.pack_lanes(&buf[..chunk.len()], w);
            }
            if !meta.elide_sign {
                for chunk in vals.chunks(64) {
                    let mut sw = 0u64;
                    for &v in chunk {
                        sw = (sw << 1) | (v.to_bits() >> 31) as u64;
                    }
                    sign.push_word(sw, chunk.len() as u32);
                }
            }
        }
        Kernel::Scalar => {
            for &e in &emaxs {
                exp.push(e as u64, eb);
            }
            for &m in &fields {
                mant.push(m as u64, w);
            }
            if !meta.elide_sign {
                for &v in vals {
                    sign.push((v.to_bits() >> 31) as u64, 1);
                }
            }
        }
    }
    let (ew, ebits) = exp.into_words();
    let (mw, mb) = mant.into_words();
    let (sw, sb) = sign.into_words();
    let bits = ComponentBits {
        sign: sb as f64,
        exponent: ebits as f64,
        mantissa: mb as f64,
        metadata: 0.0,
    };
    EncodedStreams {
        count: vals.len(),
        streams: vec![(ew, ebits), (Vec::new(), 0), (mw, mb), (sw, sb)],
        bits,
    }
}

fn decode_block_streams(
    count: usize,
    streams: &mut [SegReader<'_>],
    meta: &ContainerMeta,
    kernel: Kernel,
) -> Vec<f32> {
    let [exp, _metadata, mant, sign] = streams else {
        panic!("block layout expects 4 streams");
    };
    let n = meta.mant();
    let block = meta.layout.block().expect("block layout");
    let eb = meta.layout.field_bits();
    let w = n + 1;
    let nblocks = count.div_ceil(block.max(1));
    let mut emaxs = Vec::with_capacity(nblocks);
    match kernel {
        Kernel::Word => {
            let mut buf = [0u64; 64];
            let mut rem = nblocks;
            while rem > 0 {
                let l = rem.min(64);
                exp.unpack_lanes(eb, &mut buf[..l]);
                emaxs.extend(buf[..l].iter().map(|&e| e as u8));
                rem -= l;
            }
            let mut out = Vec::with_capacity(count);
            let mut mf = [0u64; 64];
            let mut i = 0usize;
            let mut rem = count;
            while rem > 0 {
                let l = rem.min(64);
                mant.unpack_lanes(w, &mut mf[..l]);
                let sw = if meta.elide_sign { 0 } else { sign.read_word(l as u32) };
                for (c, &m) in mf[..l].iter().enumerate() {
                    let s = if meta.elide_sign {
                        0
                    } else {
                        ((sw >> (l - 1 - c)) & 1) as u32
                    };
                    out.push(block_value(emaxs[i / block], m as u32, s, n));
                    i += 1;
                }
                rem -= l;
            }
            out
        }
        Kernel::Scalar => {
            for _ in 0..nblocks {
                emaxs.push(exp.read(eb) as u8);
            }
            (0..count)
                .map(|i| {
                    let m = mant.read(w) as u32;
                    let s = if meta.elide_sign { 0 } else { sign.read(1) as u32 };
                    block_value(emaxs[i / block], m, s, n)
                })
                .collect()
        }
    }
}

/// The learned exponent bias register the SFP hardware layout uses for a
/// tensor stored under `meta` — Quantum Exponent's per-layer fixed-bias
/// choice (and AdaptivFloat's learned tensor bias) carries straight into
/// the §V stream (see [`SfpCodec::bias`]).
fn sfp_bias_of(meta: &ContainerMeta) -> Option<u8> {
    match meta.layout {
        ExponentLayout::Width { mode: Mode::Delta, .. } => None,
        ExponentLayout::Width {
            mode: Mode::FixedBias { bias, .. },
            ..
        } => Some(bias),
        ExponentLayout::Bias { bias, .. } => Some(bias),
        ExponentLayout::BlockShared { .. } => None,
    }
}

/// Hardware-layout adapter over [`SfpCodec`] (§V interleaved bursts).
#[derive(Debug, Default, Clone, Copy)]
pub struct SfpStashCodec;

impl StashCodec for SfpStashCodec {
    fn name(&self) -> &'static str {
        "sfp"
    }

    fn group(&self, meta: &ContainerMeta) -> usize {
        match meta.layout {
            ExponentLayout::BlockShared { block, .. } => block.max(1),
            _ => crate::sfp::GROUP,
        }
    }

    fn encode_kernel(&self, vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
        if matches!(meta.layout, ExponentLayout::BlockShared { .. }) {
            // no per-value exponents for the 8-lane stack to compress —
            // the hardware stream degenerates to the block component
            // layout (shared with the gecko adapter)
            return encode_block_streams(vals, meta, kernel);
        }
        let codec = SfpCodec::new(meta.container, meta.elide_sign).with_bias(sfp_bias_of(meta));
        // AdaptivFloat windows the values first; the bias register then
        // narrows every row's exponent deltas around the learned bias
        let owned;
        let src: &[f32] = if matches!(meta.layout, ExponentLayout::Bias { .. }) {
            owned = meta.quantized_slice(vals);
            &owned
        } else {
            vals
        };
        let c = codec.compress_kernel(src, meta.mant(), kernel);
        let padded = if vals.is_empty() {
            0
        } else {
            vals.len().div_ceil(crate::sfp::GROUP) * crate::sfp::GROUP
        };
        // Component split of the interleaved payload: mantissa and sign
        // widths are fixed per (padded) value; the remainder is exponent.
        let mant = (c.mant_bits as usize * padded) as f64;
        let sign = if meta.elide_sign { 0.0 } else { padded as f64 };
        let bits = ComponentBits {
            sign,
            mantissa: mant,
            exponent: c.payload_bits as f64 - mant - sign,
            metadata: c.metadata_bits as f64,
        };
        EncodedStreams {
            count: vals.len(),
            streams: vec![(c.payload, c.payload_bits), (c.metadata, c.metadata_bits)],
            bits,
        }
    }

    fn decode_view_kernel(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32> {
        if matches!(meta.layout, ExponentLayout::BlockShared { .. }) {
            return decode_block_streams(count, streams, meta, kernel);
        }
        let [payload, metadata] = streams else {
            panic!("sfp codec expects 2 streams");
        };
        let codec = SfpCodec::new(meta.container, meta.elide_sign).with_bias(sfp_bias_of(meta));
        codec.decompress_readers_kernel(payload, metadata, count, meta.mant(), kernel)
    }
}

/// Uncompressed-container baseline: quantized values stored verbatim
/// (32 b/value FP32, 16 b/value BF16).  Ignores sign elision — the
/// container layout is fixed.
#[derive(Debug, Default, Clone, Copy)]
pub struct RawStashCodec;

impl StashCodec for RawStashCodec {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn group(&self, meta: &ContainerMeta) -> usize {
        // block-shared quantization needs whole blocks per chunk
        meta.layout.block().unwrap_or(1)
    }

    fn encode_kernel(&self, vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
        let q = meta.quantized_slice(vals);
        let total = meta.container.total_bits();
        let mut w = BitWriter::with_capacity(vals.len() * total as usize);
        match kernel {
            Kernel::Word => {
                let mut fields = [0u64; 64];
                for chunk in q.chunks(64) {
                    for (f, &qv) in fields.iter_mut().zip(chunk) {
                        *f = match meta.container {
                            Container::Fp32 => qv.to_bits() as u64,
                            Container::Bf16 => bf16_bits(qv) as u64,
                        };
                    }
                    w.pack_lanes(&fields[..chunk.len()], total);
                }
            }
            Kernel::Scalar => {
                for &qv in &q {
                    match meta.container {
                        Container::Fp32 => w.push(qv.to_bits() as u64, 32),
                        Container::Bf16 => w.push(bf16_bits(qv) as u64, 16),
                    }
                }
            }
        }
        let (words, len) = w.into_words();
        let count = vals.len() as f64;
        let bits = ComponentBits {
            sign: count,
            exponent: 8.0 * count,
            mantissa: (total as f64 - 9.0) * count,
            metadata: 0.0,
        };
        EncodedStreams {
            count: vals.len(),
            streams: vec![(words, len)],
            bits,
        }
    }

    fn decode_view_kernel(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32> {
        let [r] = streams else {
            panic!("raw codec expects 1 stream");
        };
        match kernel {
            Kernel::Word => {
                let total = meta.container.total_bits();
                let mut out = Vec::with_capacity(count);
                let mut fields = [0u64; 64];
                let mut rem = count;
                while rem > 0 {
                    let l = rem.min(64);
                    r.unpack_lanes(total, &mut fields[..l]);
                    match meta.container {
                        Container::Fp32 => {
                            out.extend(fields[..l].iter().map(|&f| f32::from_bits(f as u32)));
                        }
                        Container::Bf16 => {
                            let lanes = fields[..l].iter();
                            out.extend(lanes.map(|&f| f32::from_bits((f as u32) << 16)));
                        }
                    }
                    rem -= l;
                }
                out
            }
            Kernel::Scalar => (0..count)
                .map(|_| match meta.container {
                    Container::Fp32 => f32::from_bits(r.read(32) as u32),
                    Container::Bf16 => f32::from_bits((r.read(16) as u32) << 16),
                })
                .collect(),
        }
    }
}

/// JS zero-skip baseline (§VI-B) over the stored container: one tag bit
/// per value; non-zero values additionally store their full container
/// word.  Bit-for-bit the [`crate::baselines::js_bits`] accounting, so
/// the analytic Fig. 13 bars and the stash-measured bytes agree exactly.
/// A value is "zero" when it *quantizes* to +0.0 under `meta` (post-ReLU
/// activations — the sparsity JS exploits); −0.0 keeps its sign bit and
/// is stored, so decoding stays lossless after quantization.  Like the
/// raw baseline, the container layout is fixed: sign elision is ignored.
#[derive(Debug, Default, Clone, Copy)]
pub struct JsStashCodec;

impl StashCodec for JsStashCodec {
    fn name(&self) -> &'static str {
        "js"
    }

    fn group(&self, meta: &ContainerMeta) -> usize {
        // block-shared quantization needs whole blocks per chunk
        meta.layout.block().unwrap_or(1)
    }

    fn encode_kernel(&self, vals: &[f32], meta: &ContainerMeta, kernel: Kernel) -> EncodedStreams {
        let qs = meta.quantized_slice(vals);
        let total = meta.container.total_bits();
        let mut tags = BitWriter::with_capacity(vals.len());
        let mut payload = BitWriter::with_capacity(vals.len() * total as usize / 2);
        let mut nonzero = 0usize;
        match kernel {
            Kernel::Word => {
                // Tag plane: 64 tag bits gathered into one word splice;
                // payload plane: the chunk's non-zero container words
                // compacted left and packed in one `pack_lanes` call.
                let mut fields = [0u64; 64];
                for chunk in qs.chunks(64) {
                    let mut tagw = 0u64;
                    let mut stored = 0usize;
                    for &q in chunk {
                        let keep = q.to_bits() != 0;
                        tagw = (tagw << 1) | keep as u64;
                        if keep {
                            fields[stored] = match meta.container {
                                Container::Fp32 => q.to_bits() as u64,
                                Container::Bf16 => bf16_bits(q) as u64,
                            };
                            stored += 1;
                        }
                    }
                    tags.push_word(tagw, chunk.len() as u32);
                    payload.pack_lanes(&fields[..stored], total);
                    nonzero += stored;
                }
            }
            Kernel::Scalar => {
                for &q in &qs {
                    let stored = q.to_bits() != 0;
                    tags.push(stored as u64, 1);
                    if stored {
                        nonzero += 1;
                        match meta.container {
                            Container::Fp32 => payload.push(q.to_bits() as u64, 32),
                            Container::Bf16 => payload.push(bf16_bits(q) as u64, 16),
                        }
                    }
                }
            }
        }
        let (tw, tb) = tags.into_words();
        let (pw, pb) = payload.into_words();
        let nz = nonzero as f64;
        let bits = ComponentBits {
            sign: nz,
            exponent: 8.0 * nz,
            mantissa: (total as f64 - 9.0) * nz,
            // the per-value tag bit is the scheme's only metadata
            metadata: tb as f64,
        };
        EncodedStreams {
            count: vals.len(),
            streams: vec![(tw, tb), (pw, pb)],
            bits,
        }
    }

    fn decode_view_kernel(
        &self,
        count: usize,
        streams: &mut [SegReader<'_>],
        meta: &ContainerMeta,
        kernel: Kernel,
    ) -> Vec<f32> {
        let [tags, payload] = streams else {
            panic!("js codec expects 2 streams");
        };
        match kernel {
            Kernel::Word => {
                let total = meta.container.total_bits();
                let mut out = Vec::with_capacity(count);
                let mut fields = [0u64; 64];
                let mut rem = count;
                while rem > 0 {
                    let l = rem.min(64);
                    // popcount of the tag word tells how many container
                    // words to bulk-read before positions are assigned
                    let tagw = tags.read_word(l as u32);
                    let stored = tagw.count_ones() as usize;
                    payload.unpack_lanes(total, &mut fields[..stored]);
                    let mut k = 0usize;
                    for c in 0..l {
                        if (tagw >> (l - 1 - c)) & 1 == 0 {
                            out.push(0.0);
                        } else {
                            let f = fields[k] as u32;
                            k += 1;
                            out.push(match meta.container {
                                Container::Fp32 => f32::from_bits(f),
                                Container::Bf16 => f32::from_bits(f << 16),
                            });
                        }
                    }
                    rem -= l;
                }
                out
            }
            Kernel::Scalar => (0..count)
                .map(|_| {
                    if tags.read(1) == 0 {
                        0.0
                    } else {
                        match meta.container {
                            Container::Fp32 => f32::from_bits(payload.read(32) as u32),
                            Container::Bf16 => f32::from_bits((payload.read(16) as u32) << 16),
                        }
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traces::ValueModel;

    fn codecs() -> Vec<Box<dyn StashCodec>> {
        vec![
            Box::new(GeckoStashCodec),
            Box::new(SfpStashCodec),
            Box::new(RawStashCodec),
            Box::new(JsStashCodec),
        ]
    }

    #[test]
    fn roundtrip_is_quantization_all_codecs() {
        let vals = ValueModel::weights().sample_values(777, 3, false);
        for codec in codecs() {
            for n in [0u32, 1, 4, 7, 23] {
                for container in [Container::Fp32, Container::Bf16] {
                    let meta = ContainerMeta::new(container, n);
                    let enc = codec.encode(&vals, &meta);
                    let back = codec.decode(&enc, &meta);
                    assert_eq!(back.len(), vals.len());
                    for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                        assert_eq!(
                            meta.quantized(v).to_bits(),
                            b.to_bits(),
                            "{} n={n} {container} i={i}",
                            codec.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_equals_one_shot_all_codecs() {
        let vals = ValueModel::relu_act().sample_values(64 * 4 + 19, 5, true);
        let meta = ContainerMeta::new(Container::Bf16, 3).with_sign_elision(true);
        for codec in codecs() {
            let one = codec.encode(&vals, &meta);
            for chunk in [1usize, 64, 100, 129] {
                let cat = codec.encode_chunked(&vals, &meta, chunk);
                assert_eq!(cat.count, one.count, "{} chunk {chunk}", codec.name());
                assert_eq!(
                    cat.streams, one.streams,
                    "{} chunk {chunk}",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn gecko_component_split_matches_streams() {
        let vals = ValueModel::relu_act().sample_values(1000, 9, true);
        let meta = ContainerMeta::new(Container::Bf16, 2).with_sign_elision(true);
        let enc = GeckoStashCodec.encode(&vals, &meta);
        assert_eq!(enc.bits.total() as usize, enc.total_bits());
        assert_eq!(enc.bits.sign, 0.0);
        assert_eq!(enc.bits.mantissa, 2.0 * 1000.0);
    }

    #[test]
    fn sfp_component_split_matches_streams() {
        let vals = ValueModel::weights().sample_values(640, 11, false);
        let meta = ContainerMeta::new(Container::Fp32, 5);
        let enc = SfpStashCodec.encode(&vals, &meta);
        assert!((enc.bits.total() - enc.total_bits() as f64).abs() < 1e-9);
        assert_eq!(enc.bits.mantissa, 5.0 * 640.0);
        assert_eq!(enc.bits.sign, 640.0);
    }

    #[test]
    fn raw_bf16_is_16_bits_per_value() {
        let vals = ValueModel::weights().sample_values(100, 13, false);
        let meta = ContainerMeta::new(Container::Bf16, 7);
        let enc = RawStashCodec.encode(&vals, &meta);
        assert_eq!(enc.total_bits(), 1600);
    }

    #[test]
    fn empty_tensor_all_codecs() {
        let meta = ContainerMeta::new(Container::Fp32, 4);
        for codec in codecs() {
            let enc = codec.encode(&[], &meta);
            assert_eq!(enc.total_bits(), 0);
            assert!(codec.decode(&enc, &meta).is_empty());
        }
    }

    #[test]
    fn view_decode_over_split_segments_matches_owned() {
        // decode_view over word-split streams (as arena chunk runs would
        // present them) must equal the materialized decode bit-for-bit
        let vals = ValueModel::relu_act().sample_values(3000, 21, true);
        for meta in [
            ContainerMeta::new(Container::Bf16, 3).with_sign_elision(true),
            ContainerMeta::new(Container::Fp32, 7)
                .with_exp_mode(crate::gecko::Mode::FixedBias { bias: 126, group: 8 }),
        ] {
            for codec in codecs() {
                let enc = codec.encode(&vals, &meta);
                let owned = codec.decode(&enc, &meta);
                let split_segs: Vec<(Vec<&[u64]>, usize)> = enc
                    .streams
                    .iter()
                    .map(|(words, bits)| {
                        let mid = words.len() / 2;
                        (vec![&words[..mid], &words[mid..]], *bits)
                    })
                    .collect();
                let mut readers: Vec<SegReader> = split_segs
                    .iter()
                    .map(|(segs, bits)| SegReader::new(segs, *bits))
                    .collect();
                let viewed = codec.decode_view(enc.count, &mut readers, &meta);
                assert_eq!(owned.len(), viewed.len(), "{}", codec.name());
                for (a, b) in owned.iter().zip(&viewed) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", codec.name());
                }
            }
        }
    }

    /// Word-parallel and scalar kernels must produce byte-identical stream
    /// vectors for every codec — the invariant that keeps content hashes,
    /// cache entries, and manifest fingerprints kernel-independent.
    #[test]
    fn word_kernel_bit_identical_all_codecs() {
        let acts = ValueModel::relu_act().sample_values(64 * 7 + 13, 29, true);
        let weights = ValueModel::weights().sample_values(1000, 31, false);
        for (vals, elide) in [(&acts, true), (&weights, false)] {
            for codec in codecs() {
                for container in [Container::Fp32, Container::Bf16] {
                    for n in [0u32, 1, 7] {
                        for mode in [Mode::Delta, Mode::FixedBias { bias: 127, group: 8 }] {
                            let meta = ContainerMeta::new(container, n)
                                .with_sign_elision(elide)
                                .with_exp_mode(mode);
                            let w = codec.encode_kernel(vals, &meta, Kernel::Word);
                            let s = codec.encode_kernel(vals, &meta, Kernel::Scalar);
                            let ctx = format!("{} {container} n={n} {mode:?}", codec.name());
                            assert_eq!(w.count, s.count, "{ctx}");
                            assert_eq!(w.streams, s.streams, "{ctx}");
                            for kernel in [Kernel::Word, Kernel::Scalar] {
                                let back = codec.decode_kernel(&w, &meta, kernel);
                                for (i, (&v, &b)) in vals.iter().zip(&back).enumerate() {
                                    assert_eq!(
                                        meta.quantized(v).to_bits(),
                                        b.to_bits(),
                                        "{ctx} {kernel:?} i={i}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn word_kernel_chunked_encode_matches_scalar_one_shot() {
        // The strongest cross-path identity: chunked word-parallel encode
        // (the production pool path) equals scalar one-shot bit-for-bit.
        let vals = ValueModel::relu_act().sample_values(64 * 5 + 37, 33, true);
        let meta = ContainerMeta::new(Container::Bf16, 3).with_sign_elision(true);
        for codec in codecs() {
            let scalar = codec.encode_kernel(&vals, &meta, Kernel::Scalar);
            for chunk in [64usize, 100, 129] {
                let word = codec.encode_chunked_kernel(&vals, &meta, chunk, Kernel::Word);
                assert_eq!(word.streams, scalar.streams, "{} chunk {chunk}", codec.name());
            }
        }
    }

    #[test]
    fn js_layout_matches_baseline_accounting_exactly() {
        // The stash-measured JS bytes must equal baselines::js_bits at the
        // stream's actual post-quantization zero fraction — that identity
        // is what lets the Fig. 13 combined bars run on real bytes.
        let meta = ContainerMeta::new(Container::Bf16, 3);
        let vals = ValueModel::relu_act().sample_values(10_000, 17, true);
        let enc = JsStashCodec.encode(&vals, &meta);
        let zeros = vals.iter().filter(|&&v| meta.quantized(v).to_bits() == 0).count();
        let zero_frac = zeros as f64 / vals.len() as f64;
        assert!(zero_frac > 0.2, "relu stream should be sparse: {zero_frac}");
        let analytic = crate::baselines::js_bits(vals.len(), zero_frac, Container::Bf16);
        assert_eq!(enc.total_bits(), analytic);
        assert!((enc.bits.total() - analytic as f64).abs() < 1e-9);
        // sparse stream: JS beats the dense raw container
        let raw = RawStashCodec.encode(&vals, &meta);
        assert!(enc.total_bits() < raw.total_bits());
        // and a negative-zero survives the round trip with its sign bit
        let tricky = [0.0f32, -0.0, 1.5, 0.0];
        let enc = JsStashCodec.encode(&tricky, &meta);
        let back = JsStashCodec.decode(&enc, &meta);
        for (&v, &b) in tricky.iter().zip(&back) {
            assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
        }
    }

    fn layouts_under_test() -> Vec<ExponentLayout> {
        vec![
            ExponentLayout::Bias { bits: 8, bias: 127 },
            ExponentLayout::Bias { bits: 4, bias: 127 },
            ExponentLayout::Bias { bits: 2, bias: 120 },
            ExponentLayout::Bias { bits: 4, bias: 1 },
            ExponentLayout::Bias { bits: 4, bias: 254 },
            ExponentLayout::BlockShared { block: 16, bits: 8 },
            ExponentLayout::BlockShared { block: 7, bits: 8 },
            ExponentLayout::BlockShared { block: 16, bits: 6 },
            ExponentLayout::BlockShared { block: 1, bits: 8 },
        ]
    }

    #[test]
    fn new_layouts_roundtrip_to_slice_quantization_all_codecs() {
        // bit-exact restore for every layout × codec × container ×
        // mantissa corner, on a ragged length (1003 is not a multiple of
        // any block size under test)
        let vals = ValueModel::weights().sample_values(1003, 41, false);
        for layout in layouts_under_test() {
            for codec in codecs() {
                for n in [0u32, 1, 4, 7] {
                    for container in [Container::Fp32, Container::Bf16] {
                        let meta = ContainerMeta::new(container, n).with_layout(layout);
                        let q = meta.quantized_slice(&vals);
                        let enc = codec.encode(&vals, &meta);
                        let back = codec.decode(&enc, &meta);
                        assert_eq!(back.len(), vals.len());
                        for (i, (&want, &got)) in q.iter().zip(&back).enumerate() {
                            assert_eq!(
                                want.to_bits(),
                                got.to_bits(),
                                "{} {} n={n} {container} i={i}",
                                codec.name(),
                                layout.label()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn new_layouts_word_kernel_bit_identical_all_codecs() {
        let acts = ValueModel::relu_act().sample_values(64 * 3 + 29, 43, true);
        let weights = ValueModel::weights().sample_values(777, 47, false);
        for (vals, elide) in [(&acts, true), (&weights, false)] {
            for layout in layouts_under_test() {
                for codec in codecs() {
                    for n in [0u32, 1, 7] {
                        let meta = ContainerMeta::new(Container::Bf16, n)
                            .with_sign_elision(elide)
                            .with_layout(layout);
                        let w = codec.encode_kernel(vals, &meta, Kernel::Word);
                        let s = codec.encode_kernel(vals, &meta, Kernel::Scalar);
                        let ctx = format!("{} {} n={n}", codec.name(), layout.label());
                        assert_eq!(w.count, s.count, "{ctx}");
                        assert_eq!(w.streams, s.streams, "{ctx}");
                        let q = meta.quantized_slice(vals);
                        for kernel in [Kernel::Word, Kernel::Scalar] {
                            let back = codec.decode_kernel(&w, &meta, kernel);
                            for (i, (&want, &got)) in q.iter().zip(&back).enumerate() {
                                assert_eq!(
                                    want.to_bits(),
                                    got.to_bits(),
                                    "{ctx} {kernel:?} i={i}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn new_layouts_chunked_equals_one_shot() {
        // chunk boundaries land mid-block unless the codec group aligns
        // them — this is what `group()` guarantees for the new layouts
        let vals = ValueModel::relu_act().sample_values(16 * 13 + 9, 51, true);
        for layout in layouts_under_test() {
            let meta = ContainerMeta::new(Container::Bf16, 3)
                .with_sign_elision(true)
                .with_layout(layout);
            for codec in codecs() {
                let one = codec.encode(&vals, &meta);
                for chunk in [1usize, 16, 100, 129] {
                    let cat = codec.encode_chunked(&vals, &meta, chunk);
                    assert_eq!(
                        cat.streams,
                        one.streams,
                        "{} {} chunk {chunk}",
                        codec.name(),
                        layout.label()
                    );
                }
            }
        }
    }

    #[test]
    fn block_shared_component_split_and_footprint() {
        // exponent bits amortize across the block: bits × nblocks exactly,
        // mantissa stream carries the explicit leading one
        let vals = ValueModel::weights().sample_values(1000, 53, false);
        let layout = ExponentLayout::BlockShared { block: 16, bits: 8 };
        let meta = ContainerMeta::new(Container::Bf16, 4).with_layout(layout);
        for codec in [&GeckoStashCodec as &dyn StashCodec, &SfpStashCodec] {
            let enc = codec.encode(&vals, &meta);
            assert_eq!(enc.bits.total() as usize, enc.total_bits());
            assert_eq!(enc.bits.exponent, 8.0 * 63.0); // ceil(1000 / 16) blocks
            assert_eq!(enc.bits.mantissa, 5.0 * 1000.0);
            assert_eq!(enc.bits.sign, 1000.0);
            assert_eq!(enc.bits.metadata, 0.0);
        }
        // gecko and sfp degenerate to the same stream bytes under a
        // shared-exponent layout
        let g = GeckoStashCodec.encode(&vals, &meta);
        let s = SfpStashCodec.encode(&vals, &meta);
        assert_eq!(g.streams, s.streams);
    }

    #[test]
    fn bias_component_split_matches_fixed_field() {
        let vals = ValueModel::weights().sample_values(1000, 59, false);
        let layout = ExponentLayout::Bias { bits: 4, bias: 127 };
        let meta = ContainerMeta::new(Container::Bf16, 3).with_layout(layout);
        let enc = GeckoStashCodec.encode(&vals, &meta);
        assert_eq!(enc.bits.total() as usize, enc.total_bits());
        assert_eq!(enc.bits.exponent, 4.0 * 1000.0);
        assert_eq!(enc.bits.mantissa, 3.0 * 1000.0);
        assert_eq!(enc.bits.sign, 1000.0);
        assert_eq!(enc.bits.metadata, 0.0);
        // 8 bits/value total — the fp8 preset's exact footprint
        assert_eq!(enc.total_bits(), 8 * 1000);
    }

    #[test]
    fn bias_sfp_stream_narrows_around_learned_bias() {
        // the AdaptivFloat bias carried into the §V bias registers must
        // narrow the hardware stream vs. the delta layout, and still
        // restore bit-exactly to the windowed quantization
        let vals = ValueModel::weights().sample_values(64 * 64, 61, false);
        let delta = ContainerMeta::new(Container::Bf16, 2);
        let af = delta.with_layout(ExponentLayout::Bias { bits: 8, bias: 121 });
        let enc_delta = SfpStashCodec.encode(&vals, &delta);
        let enc_af = SfpStashCodec.encode(&vals, &af);
        assert!(
            enc_af.total_bits() < enc_delta.total_bits(),
            "af {} vs delta {}",
            enc_af.total_bits(),
            enc_delta.total_bits()
        );
        let q = af.quantized_slice(&vals);
        let back = SfpStashCodec.decode(&enc_af, &af);
        for (&want, &got) in q.iter().zip(&back) {
            assert_eq!(want.to_bits(), got.to_bits());
        }
    }

    #[test]
    fn sfp_codec_uses_learned_bias_registers() {
        // A FixedBias meta (Quantum Exponent's output) must narrow the sfp
        // payload vs the raw row-0-base layout on trained-like streams
        // (weights: tight exponent cluster, no zeros), and still
        // round-trip bit-exact.
        let vals = ValueModel::weights().sample_values(64 * 64, 5, false);
        let delta = ContainerMeta::new(Container::Bf16, 2);
        let biased = delta.with_exp_mode(crate::gecko::Mode::FixedBias { bias: 121, group: 8 });
        let enc_delta = SfpStashCodec.encode(&vals, &delta);
        let enc_biased = SfpStashCodec.encode(&vals, &biased);
        assert!(
            enc_biased.total_bits() < enc_delta.total_bits(),
            "biased {} vs delta {}",
            enc_biased.total_bits(),
            enc_delta.total_bits()
        );
        let back = SfpStashCodec.decode(&enc_biased, &biased);
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(biased.quantized(v).to_bits(), b.to_bits());
        }
    }
}
