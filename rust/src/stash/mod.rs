//! Concurrent compressed-tensor stash — the memory path between forward
//! and backward.
//!
//! The paper's premise (§III) is that *stashed* activations and weights —
//! written after the forward pass, read back for the backward — dominate
//! off-chip traffic, and that adaptive containers shrink them 3–5×.  This
//! subsystem actually *holds* those tensors compressed between the passes
//! instead of only counting bits analytically:
//!
//! ```text
//!  put(id, vals, meta) ──▶ [StashPool workers] ── encode_chunked ──▶
//!        ▲ back-pressure        │ StashCodec (gecko / sfp / raw / js)
//!        │ (bounded queue)      ▼
//!        │                 [ChunkArena]  fixed 32 KiB chunks, free-list reuse
//!        │                   │      │ budget crossed: cold runs evict ▼
//!        │                   │      └──────────▶ [spill file] ◀ fault on pin
//!        │                   ▼ pin (Arc, zero-copy)
//!  take(id) ◀ decode_view ◀──┘   every write/read/evict/fault ──▶ [StashLedger]
//! ```
//!
//! * [`codec::StashCodec`] — pluggable encode/decode, adapters over the
//!   existing Gecko, SFP, JS zero-skip, and raw baseline stacks; per-tensor
//!   [`codec::ContainerMeta`] carries the mantissa bitlength and the
//!   exponent [`crate::formats::ExponentLayout`] the active policy chose —
//!   per-value width (Quantum Exponent / BitWave), fixed-bias window
//!   (AdaptivFloat), or block shared exponent (Flexpoint; blocks align
//!   with chunk boundaries so chunked encodes stay bit-exact).
//!   Decoding is zero-copy: [`codec::StashCodec::decode_view`] reads
//!   pinned arena chunks in place through segmented bit readers.
//! * [`arena::ChunkArena`] — tiered chunk storage: a free-list-recycled
//!   DRAM tier plus a budget-driven file-backed spill tier (cold chunk
//!   runs evict when resident bytes cross [`StashConfig::budget_bytes`],
//!   and fault back on demand).
//! * [`pool::StashPool`] — bounded-queue encode/decode worker threads.
//! * [`ledger::StashLedger`] — exact stored-bits + bandwidth accounting,
//!   split into DRAM and spill traffic; feeds `report::footprint`
//!   comparisons and `hwsim`'s DRAM model, with atomic per-epoch cuts.
//!
//! Restores come in two shapes: the blocking [`Stash::take`]/
//! [`Stash::take_all`], and [`Stash::take_deferred`], which removes the
//! entries immediately but runs the decodes on the pool — the
//! restore-prefetch half of the Trainer's double-buffered pipeline (step
//! N−1's decodes and step N's encodes both overlap the compiled step).
//!
//! Consumers: `coordinator::train::Trainer` (opt-in per-step stashing on
//! the request path) and the `repro stash` sweep/verification command
//! (`--budget-bytes` sweeps the spill tier).

pub mod arena;
pub mod codec;
pub mod ledger;
pub mod pool;

pub use arena::{ChunkArena, ChunkSeq, PinnedStream, TenantStats, CHUNK_BYTES, CHUNK_WORDS};
pub use codec::{
    ContainerMeta, EncodedStreams, GeckoStashCodec, JsStashCodec, RawStashCodec, SfpStashCodec,
    StashCodec,
};
pub use ledger::{EpochTraffic, LedgerSnapshot, StashLedger, TensorClass};
pub use pool::StashPool;

use crate::gecko::SegReader;
use crate::obs::metrics::HistSummary;
use crate::stats::ComponentBits;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Which codec adapter a stash uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    Gecko,
    Sfp,
    Raw,
    /// JS zero-skip baseline (tag bit + container word per non-zero).
    Js,
}

impl CodecKind {
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s {
            "gecko" => Some(CodecKind::Gecko),
            "sfp" => Some(CodecKind::Sfp),
            "raw" | "dense" => Some(CodecKind::Raw),
            "js" => Some(CodecKind::Js),
            _ => None,
        }
    }

    pub fn build(self) -> Arc<dyn StashCodec> {
        match self {
            CodecKind::Gecko => Arc::new(GeckoStashCodec),
            CodecKind::Sfp => Arc::new(SfpStashCodec),
            CodecKind::Raw => Arc::new(RawStashCodec),
            CodecKind::Js => Arc::new(JsStashCodec),
        }
    }

    /// All registered codecs (the lab grid's codec axis).
    pub fn all() -> [CodecKind; 4] {
        [CodecKind::Gecko, CodecKind::Sfp, CodecKind::Raw, CodecKind::Js]
    }

    pub fn label(self) -> &'static str {
        match self {
            CodecKind::Gecko => "gecko",
            CodecKind::Sfp => "sfp",
            CodecKind::Raw => "raw",
            CodecKind::Js => "js",
        }
    }

    /// Position on the per-codec metrics axis
    /// ([`crate::obs::metrics::CODEC_LABELS`] shares [`CodecKind::all`]'s
    /// order).
    pub fn index(self) -> usize {
        match self {
            CodecKind::Gecko => 0,
            CodecKind::Sfp => 1,
            CodecKind::Raw => 2,
            CodecKind::Js => 3,
        }
    }
}

/// Stash construction knobs (all zeros = sensible defaults).
#[derive(Debug, Clone, Copy)]
pub struct StashConfig {
    pub codec: CodecKind,
    /// Worker threads; 0 = available parallelism.
    pub threads: usize,
    /// Bounded submit-queue depth; 0 = 2× threads.
    pub queue_depth: usize,
    /// Encode chunk granularity in values (rounded up to the codec group);
    /// 0 = 64 Ki values.
    pub chunk_values: usize,
    /// DRAM budget for the arena's resident tier in bytes; 0 = unlimited
    /// (spill tier disabled).  When live resident bytes cross the budget,
    /// cold chunk runs evict to a file-backed spill region and fault back
    /// on demand — batch sizes beyond DRAM become a sweep axis.
    pub budget_bytes: usize,
}

impl Default for StashConfig {
    fn default() -> Self {
        Self {
            codec: CodecKind::Gecko,
            threads: 0,
            queue_depth: 0,
            chunk_values: 0,
            budget_bytes: 0,
        }
    }
}

/// Key of one stashed tensor within a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TensorId {
    pub class: TensorClass,
    pub layer: usize,
}

impl TensorId {
    pub fn act(layer: usize) -> TensorId {
        TensorId {
            class: TensorClass::Activation,
            layer,
        }
    }

    pub fn weight(layer: usize) -> TensorId {
        TensorId {
            class: TensorClass::Weight,
            layer,
        }
    }
}

/// One resident tensor: arena handles per codec stream + bookkeeping.
struct StoredTensor {
    /// Submission order of the `put` that produced this entry — encode jobs
    /// for the same id may finish out of order on different workers, and
    /// only the newest submission may win.
    seq: u64,
    count: usize,
    meta: ContainerMeta,
    streams: Vec<ChunkSeq>,
    bits: ComponentBits,
}

type Store = Mutex<HashMap<TensorId, StoredTensor>>;

/// The concurrent compressed-tensor stash.
pub struct Stash {
    codec: Arc<dyn StashCodec>,
    /// Which codec adapter `codec` is — the per-codec metrics axis.
    kind: CodecKind,
    arena: Arc<ChunkArena>,
    ledger: Arc<StashLedger>,
    store: Arc<Store>,
    pool: StashPool,
    chunk_values: usize,
    put_seq: AtomicU64,
    /// Arena tenant this stash stores under (0 = sole owner of a private
    /// arena; leased facades over a shared arena carry their lease's id).
    tenant: u32,
}

impl Stash {
    pub fn new(cfg: StashConfig) -> Stash {
        let ledger = Arc::new(StashLedger::new());
        let arena = Arc::new(ChunkArena::with_budget(
            cfg.budget_bytes,
            None,
            Some(Arc::clone(&ledger)),
        ));
        Self::facade(cfg, arena, ledger, 0)
    }

    /// Per-tenant facade over a *shared* [`ChunkArena`]: every stream this
    /// stash stores is tagged with `tenant` (already registered on the
    /// arena, e.g. via [`ChunkArena::register_tenant`] or a
    /// [`crate::serve::StashService`] lease), so placement honors the
    /// tenant's budget and its spill traffic lands in `ledger` — the
    /// lease's per-tenant ledger.  `cfg.budget_bytes` is ignored: the
    /// shared arena's per-tenant and global budgets govern placement.
    pub fn with_arena(
        cfg: StashConfig,
        arena: Arc<ChunkArena>,
        ledger: Arc<StashLedger>,
        tenant: u32,
    ) -> Stash {
        Self::facade(cfg, arena, ledger, tenant)
    }

    fn facade(
        cfg: StashConfig,
        arena: Arc<ChunkArena>,
        ledger: Arc<StashLedger>,
        tenant: u32,
    ) -> Stash {
        Stash {
            codec: cfg.codec.build(),
            kind: cfg.codec,
            arena,
            ledger,
            store: Arc::new(Mutex::new(HashMap::new())),
            pool: StashPool::new(cfg.threads, cfg.queue_depth),
            chunk_values: if cfg.chunk_values == 0 {
                64 * 1024
            } else {
                cfg.chunk_values
            },
            put_seq: AtomicU64::new(0),
            tenant,
        }
    }

    /// Queue `vals` for encoding and storage under `id`.  Returns as soon
    /// as the job is accepted; blocks only on queue back-pressure.  A
    /// tensor already stored under `id` is replaced (its chunks freed).
    pub fn put(&self, id: TensorId, vals: Vec<f32>, meta: ContainerMeta) {
        // flight recorder: resident vs. spill gauges sampled at the put
        // cadence (no-op unless tracing; reads two arena atomics)
        crate::obs::timeseries::record("stash_bytes.resident", self.arena.in_use_bytes() as f64);
        crate::obs::timeseries::record("stash_bytes.spill", self.arena.spill_in_use_bytes() as f64);
        let codec = Arc::clone(&self.codec);
        let arena = Arc::clone(&self.arena);
        let ledger = Arc::clone(&self.ledger);
        let store = Arc::clone(&self.store);
        let chunk_values = self.chunk_values;
        let kind = self.kind;
        let tenant = self.tenant;
        let seq = self.put_seq.fetch_add(1, Ordering::SeqCst);
        self.pool.submit(Box::new(move || {
            let _sp = crate::obs::span("stash", "encode");
            let t0 = std::time::Instant::now();
            let enc = codec.encode_chunked(&vals, &meta, chunk_values);
            crate::obs::metrics::ENCODE_US[kind.index()].record_duration(t0.elapsed());
            crate::obs::metrics::ENCODE_BYTES[kind.index()].add((vals.len() * 4) as u64);
            let streams: Vec<ChunkSeq> = enc
                .streams
                .iter()
                .map(|(words, len)| arena.store_for(tenant, words, *len))
                .collect();
            ledger.record_write(id.class, enc.bits, enc.count);
            let fresh = StoredTensor {
                seq,
                count: enc.count,
                meta,
                streams,
                bits: enc.bits,
            };
            // Encode jobs can finish out of submission order; the newest
            // submission wins even if an older one lands afterwards.
            let loser = {
                let mut map = store.lock().unwrap();
                let newer_resident = map.get(&id).is_some_and(|e| e.seq > seq);
                if newer_resident {
                    Some(fresh)
                } else {
                    map.insert(id, fresh)
                }
            };
            if let Some(old) = loser {
                release_stored(&arena, &ledger, id.class, old);
            }
        }));
    }

    /// Barrier: wait until every queued put/take job has finished.
    pub fn flush(&self) {
        self.pool.wait_idle();
        // settled high-water sample once all encodes landed
        crate::obs::timeseries::record("stash_bytes.resident", self.arena.in_use_bytes() as f64);
        crate::obs::timeseries::record("stash_bytes.spill", self.arena.spill_in_use_bytes() as f64);
    }

    /// Decode a resident tensor without removing it.  Call after
    /// [`Stash::flush`] — a tensor still in the encode queue is not yet
    /// visible.
    pub fn get(&self, id: TensorId) -> Option<Vec<f32>> {
        // Pin the chunks under the store lock (Arc clones, plus spill
        // faults for evicted runs) so a concurrent take/discard can't
        // release them mid-read; decode outside the lock, in place.
        let (pins, count, meta, bits) = {
            let store = self.store.lock().unwrap();
            let stored = store.get(&id)?;
            let pins: Vec<PinnedStream> =
                stored.streams.iter().map(|s| self.arena.pin(s)).collect();
            (pins, stored.count, stored.meta, stored.bits)
        };
        self.ledger.record_read(bits.total());
        let segs: Vec<Vec<&[u64]>> = pins.iter().map(PinnedStream::segs).collect();
        let mut readers: Vec<SegReader> = segs
            .iter()
            .zip(&pins)
            .map(|(s, p)| SegReader::new(s, p.len_bits))
            .collect();
        Some(self.codec.decode_view(count, &mut readers, &meta))
    }

    /// Decode a tensor and remove it, returning its chunks to the arena —
    /// the restore-for-backward path (zero-copy: decodes pinned chunks in
    /// place).
    pub fn take(&self, id: TensorId) -> Option<Vec<f32>> {
        let stored = self.store.lock().unwrap().remove(&id)?;
        self.ledger.record_read(stored.bits.total());
        let vals = restore_stored(
            self.codec.as_ref(),
            &self.arena,
            &self.ledger,
            self.kind,
            &stored,
        );
        release_stored(&self.arena, &self.ledger, id.class, stored);
        Some(vals)
    }

    /// Remove `ids` from the stash immediately and queue their decodes on
    /// the worker pool *without waiting* — the restore-prefetch half of
    /// the Trainer's double buffer.  The caller overlaps other work (the
    /// compiled train step) with the decodes, then calls [`Stash::flush`]
    /// and [`RestoreTicket::collect`]s.  Because the entries leave the
    /// store synchronously, `put`s for the same ids submitted afterwards
    /// cannot race the restore.  Tensors still in the encode queue are not
    /// yet visible — flush first if puts may be outstanding.
    pub fn take_deferred(&self, ids: &[TensorId]) -> RestoreTicket {
        let results = Arc::new(Mutex::new(Vec::new()));
        results.lock().unwrap().resize_with(ids.len(), || None);
        for (slot, &id) in ids.iter().enumerate() {
            let Some(stored) = self.store.lock().unwrap().remove(&id) else {
                continue;
            };
            let codec = Arc::clone(&self.codec);
            let arena = Arc::clone(&self.arena);
            let ledger = Arc::clone(&self.ledger);
            let results = Arc::clone(&results);
            let kind = self.kind;
            self.pool.submit(Box::new(move || {
                ledger.record_read(stored.bits.total());
                let vals = restore_stored(codec.as_ref(), &arena, &ledger, kind, &stored);
                release_stored(&arena, &ledger, id.class, stored);
                results.lock().unwrap()[slot] = Some(vals);
            }));
        }
        RestoreTicket { results }
    }

    /// Decode-and-remove a batch of tensors in parallel on the pool;
    /// result slots line up with `ids` (`None` = not resident).
    pub fn take_all(&self, ids: &[TensorId]) -> Vec<Option<Vec<f32>>> {
        self.flush();
        let ticket = self.take_deferred(ids);
        self.flush();
        ticket.collect()
    }

    /// Drop a resident tensor without decoding it.
    pub fn discard(&self, id: TensorId) {
        if let Some(stored) = self.store.lock().unwrap().remove(&id) {
            release_stored(&self.arena, &self.ledger, id.class, stored);
        }
    }

    /// Component split of one resident tensor's stored bits.
    pub fn stored_bits(&self, id: TensorId) -> Option<ComponentBits> {
        self.store.lock().unwrap().get(&id).map(|s| s.bits)
    }

    /// Element count of one resident tensor.
    pub fn stored_count(&self, id: TensorId) -> Option<usize> {
        self.store.lock().unwrap().get(&id).map(|s| s.count)
    }

    pub fn resident_tensors(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    pub fn ledger(&self) -> LedgerSnapshot {
        self.ledger.snapshot()
    }

    /// Cut an epoch boundary in the ledger (footprint-over-time series).
    pub fn mark_epoch(&self) {
        self.ledger.mark_epoch();
    }

    /// Per-epoch written/read traffic between [`Stash::mark_epoch`] cuts.
    pub fn epoch_traffic(&self) -> Vec<EpochTraffic> {
        self.ledger.epoch_traffic()
    }

    pub fn arena_in_use_bytes(&self) -> usize {
        self.arena.in_use_bytes()
    }

    pub fn arena_allocated_bytes(&self) -> usize {
        self.arena.allocated_bytes()
    }

    pub fn arena_high_water_bytes(&self) -> usize {
        self.arena.high_water_bytes()
    }

    /// Live bytes currently evicted to the spill tier.
    pub fn arena_spill_bytes(&self) -> usize {
        self.arena.spill_in_use_bytes()
    }

    /// Peak concurrently-spilled bytes over the stash's lifetime.
    pub fn arena_spill_high_water_bytes(&self) -> usize {
        self.arena.spill_high_water_bytes()
    }

    /// This stash's tenant id on its (possibly shared) arena.
    pub fn tenant(&self) -> u32 {
        self.tenant
    }

    /// This tenant's accounting slice of the arena (for a sole-owner
    /// stash, tenant 0 — i.e. the whole arena).
    pub fn tenant_stats(&self) -> TenantStats {
        self.arena.tenant_stats(self.tenant)
    }

    /// Restore-latency digests from this stash's ledger: `(DRAM hit,
    /// spill fault)` — the per-tenant tier split the serve scenario
    /// aggregates.
    pub fn restore_latency(&self) -> (HistSummary, HistSummary) {
        self.ledger.restore_latency()
    }

    pub fn codec_name(&self) -> &'static str {
        self.codec.name()
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Encode/decode jobs that panicked (0 in a healthy run).
    pub fn failures(&self) -> usize {
        self.pool.failures()
    }
}

/// Handle to a batch of deferred restores queued by
/// [`Stash::take_deferred`]: collect after a [`Stash::flush`] barrier.
pub struct RestoreTicket {
    results: Arc<Mutex<Vec<Option<Vec<f32>>>>>,
}

impl RestoreTicket {
    /// Result slots line up with the `ids` passed to
    /// [`Stash::take_deferred`] (`None` = not resident).  Only complete
    /// after a [`Stash::flush`].
    pub fn collect(self) -> Vec<Option<Vec<f32>>> {
        std::mem::take(&mut *self.results.lock().unwrap())
    }
}

/// Zero-copy decode of one stored tensor: pin its chunk runs (faulting
/// spilled ones back), then decode the pinned memory in place through
/// segmented bit readers — no materialized `Vec<u64>` stream copies.
/// The flag reports whether any chunk had to be faulted back from the
/// spill tier during the pin.
fn decode_stored(
    codec: &dyn StashCodec,
    arena: &ChunkArena,
    stored: &StoredTensor,
) -> (Vec<f32>, bool) {
    let pins: Vec<PinnedStream> = stored.streams.iter().map(|s| arena.pin(s)).collect();
    let faulted = pins.iter().any(|p| p.faulted);
    let segs: Vec<Vec<&[u64]>> = pins.iter().map(PinnedStream::segs).collect();
    let mut readers: Vec<SegReader> = segs
        .iter()
        .zip(&pins)
        .map(|(s, p)| SegReader::new(s, p.len_bits))
        .collect();
    let vals = codec.decode_view(stored.count, &mut readers, &stored.meta);
    (vals, faulted)
}

/// [`decode_stored`] plus observability: a `stash/restore` span, per-codec
/// decode-latency histograms, and the ledger's per-tier (DRAM hit vs.
/// spill fault) restore-latency record.  Timing stays in metrics — it
/// never reaches artifact bytes.
fn restore_stored(
    codec: &dyn StashCodec,
    arena: &ChunkArena,
    ledger: &StashLedger,
    kind: CodecKind,
    stored: &StoredTensor,
) -> Vec<f32> {
    let _sp = crate::obs::span("stash", "restore");
    let t0 = std::time::Instant::now();
    let (vals, faulted) = decode_stored(codec, arena, stored);
    let us = t0.elapsed().as_micros() as u64;
    crate::obs::metrics::DECODE_US[kind.index()].record(us);
    crate::obs::metrics::DECODE_BYTES[kind.index()].add((vals.len() * 4) as u64);
    ledger.record_restore_latency(faulted, us);
    if faulted {
        crate::obs::metrics::RESTORE_FAULT_US.record(us);
    } else {
        crate::obs::metrics::RESTORE_DRAM_US.record(us);
    }
    vals
}

fn release_stored(
    arena: &ChunkArena,
    ledger: &StashLedger,
    class: TensorClass,
    stored: StoredTensor,
) {
    ledger.record_release(class, stored.bits);
    for seq in stored.streams {
        arena.release(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Container;
    use crate::traces::ValueModel;

    fn small_stash(kind: CodecKind) -> Stash {
        Stash::new(StashConfig {
            codec: kind,
            threads: 2,
            queue_depth: 4,
            chunk_values: 256,
            budget_bytes: 0,
        })
    }

    #[test]
    fn put_flush_take_roundtrip() {
        let stash = small_stash(CodecKind::Gecko);
        let vals = ValueModel::relu_act().sample_values(1000, 1, true);
        let meta = ContainerMeta::new(Container::Bf16, 3).with_sign_elision(true);
        stash.put(TensorId::act(0), vals.clone(), meta);
        stash.flush();
        assert_eq!(stash.resident_tensors(), 1);
        let back = stash.take(TensorId::act(0)).unwrap();
        for (&v, &b) in vals.iter().zip(&back) {
            assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
        }
        assert_eq!(stash.resident_tensors(), 0);
        assert!(stash.ledger().resident.total().abs() < 1e-9);
        assert_eq!(stash.failures(), 0);
    }

    #[test]
    fn take_all_parallel_restore() {
        let stash = small_stash(CodecKind::Sfp);
        let meta = ContainerMeta::new(Container::Fp32, 5);
        let tensors: Vec<Vec<f32>> = (0..8)
            .map(|i| ValueModel::weights().sample_values(700 + i * 13, i as u64, false))
            .collect();
        for (i, t) in tensors.iter().enumerate() {
            stash.put(TensorId::weight(i), t.clone(), meta);
        }
        let ids: Vec<TensorId> = (0..8).map(TensorId::weight).collect();
        let back = stash.take_all(&ids);
        for (t, b) in tensors.iter().zip(&back) {
            let b = b.as_ref().unwrap();
            assert_eq!(t.len(), b.len());
            for (&v, &x) in t.iter().zip(b) {
                assert_eq!(meta.quantized(v).to_bits(), x.to_bits());
            }
        }
        // missing id comes back None
        assert!(stash.take_all(&[TensorId::weight(99)])[0].is_none());
    }

    #[test]
    fn replacement_releases_old_chunks() {
        let stash = small_stash(CodecKind::Raw);
        let meta = ContainerMeta::new(Container::Fp32, 23);
        let vals = ValueModel::weights().sample_values(5000, 7, false);
        stash.put(TensorId::act(3), vals.clone(), meta);
        stash.flush();
        let resident_once = stash.ledger().resident.total();
        for _ in 0..5 {
            stash.put(TensorId::act(3), vals.clone(), meta);
            stash.flush();
        }
        // same tensor resident once, not six times
        assert!((stash.ledger().resident.total() - resident_once).abs() < 1e-9);
        assert_eq!(stash.resident_tensors(), 1);
        stash.discard(TensorId::act(3));
        assert_eq!(stash.arena_in_use_bytes(), 0);
    }

    #[test]
    fn ledger_matches_stored_bits() {
        let stash = small_stash(CodecKind::Gecko);
        let meta = ContainerMeta::new(Container::Bf16, 4);
        let vals = ValueModel::relu_act().sample_values(2000, 3, true);
        stash.put(TensorId::act(0), vals, meta);
        stash.flush();
        let bits = stash.stored_bits(TensorId::act(0)).unwrap();
        let s = stash.ledger();
        assert!((s.resident.total() - bits.total()).abs() < 1e-9);
        assert!((s.written_bits - bits.total()).abs() < 1e-9);
        assert!((s.written_fp32_bits - 32.0 * 2000.0).abs() < 1e-9);
        assert!(s.ratio_vs_fp32() < 1.0, "{}", s.ratio_vs_fp32());
    }

    #[test]
    fn latest_put_wins_without_intervening_flush() {
        // Two encode jobs for the same id race on different workers; the
        // later submission must be the one resident after the barrier,
        // whichever finishes first.
        let stash = small_stash(CodecKind::Raw);
        let meta = ContainerMeta::new(Container::Fp32, 23);
        for round in 0..20 {
            stash.put(TensorId::act(0), vec![1.0; 4096], meta);
            stash.put(TensorId::act(0), vec![2.0; 4096], meta);
            stash.flush();
            let back = stash.get(TensorId::act(0)).unwrap();
            assert!(back.iter().all(|&v| v == 2.0), "round {round}");
            stash.discard(TensorId::act(0));
        }
        assert_eq!(stash.arena_in_use_bytes(), 0);
    }

    #[test]
    fn get_keeps_tensor_resident() {
        let stash = small_stash(CodecKind::Gecko);
        let meta = ContainerMeta::new(Container::Fp32, 8);
        stash.put(TensorId::act(1), vec![1.5f32; 100], meta);
        stash.flush();
        let a = stash.get(TensorId::act(1)).unwrap();
        let b = stash.get(TensorId::act(1)).unwrap();
        assert_eq!(a, b);
        assert_eq!(stash.ledger().reads, 2);
        assert_eq!(stash.resident_tensors(), 1);
    }

    #[test]
    fn budgeted_stash_spills_and_restores_bit_exact() {
        // Budget of one chunk: several raw-FP32 tensors can't all stay
        // resident, so cold runs must spill — and every restore must still
        // be bit-exact, with the ledger reporting the tier split.
        let stash = Stash::new(StashConfig {
            codec: CodecKind::Raw,
            threads: 2,
            queue_depth: 4,
            chunk_values: 4096,
            budget_bytes: CHUNK_BYTES,
        });
        let meta = ContainerMeta::new(Container::Fp32, 23);
        let tensors: Vec<Vec<f32>> = (0..6)
            .map(|i| ValueModel::weights().sample_values(20_000, i as u64, false))
            .collect();
        for (i, t) in tensors.iter().enumerate() {
            stash.put(TensorId::act(i), t.clone(), meta);
        }
        stash.flush();
        assert_eq!(stash.failures(), 0);
        let snap = stash.ledger();
        assert!(snap.evictions > 0, "budget pressure must evict");
        assert!(snap.spill_written_bits > 0.0);
        assert!(stash.arena_spill_bytes() > 0);
        assert!(stash.arena_in_use_bytes() <= CHUNK_BYTES);
        let ids: Vec<TensorId> = (0..6).map(TensorId::act).collect();
        let back = stash.take_all(&ids);
        for (t, b) in tensors.iter().zip(&back) {
            let b = b.as_ref().unwrap();
            assert_eq!(t.len(), b.len());
            for (&v, &x) in t.iter().zip(b) {
                assert_eq!(meta.quantized(v).to_bits(), x.to_bits());
            }
        }
        let snap = stash.ledger();
        assert!(snap.faults > 0, "restores must fault spilled runs back");
        assert!(snap.spill_read_bits > 0.0);
        assert_eq!(stash.arena_spill_bytes(), 0);
        assert_eq!(stash.arena_in_use_bytes(), 0);
        assert_eq!(stash.failures(), 0);
    }

    #[test]
    fn leased_facades_share_one_arena_with_isolated_accounting() {
        // Two per-tenant facades over one shared arena: stores route to
        // their own tenant, reads stay bit-exact, and the tenant stats
        // partition the arena's accounting exactly.
        let arena = Arc::new(ChunkArena::with_budget(64 * CHUNK_BYTES, None, None));
        let la = Arc::new(StashLedger::new());
        let lb = Arc::new(StashLedger::new());
        let ta = arena.register_tenant(32 * CHUNK_BYTES, 0, Some(Arc::clone(&la)));
        let tb = arena.register_tenant(32 * CHUNK_BYTES, 0, Some(Arc::clone(&lb)));
        let cfg = StashConfig {
            codec: CodecKind::Raw,
            threads: 1,
            queue_depth: 2,
            chunk_values: 4096,
            budget_bytes: 0,
        };
        let sa = Stash::with_arena(cfg, Arc::clone(&arena), la, ta);
        let sb = Stash::with_arena(cfg, Arc::clone(&arena), lb, tb);
        let meta = ContainerMeta::new(Container::Fp32, 23);
        let va = ValueModel::weights().sample_values(20_000, 1, false);
        let vb = ValueModel::weights().sample_values(20_000, 2, false);
        sa.put(TensorId::act(0), va.clone(), meta);
        sb.put(TensorId::act(0), vb.clone(), meta);
        sa.flush();
        sb.flush();
        // each facade sees only its own tensor under the shared arena...
        assert_eq!(sa.resident_tensors(), 1);
        assert_eq!(sb.resident_tensors(), 1);
        // ...and its own accounting slice partitions the arena total
        assert!(sa.tenant_stats().in_use_bytes > 0);
        assert_eq!(
            sa.tenant_stats().in_use_bytes + sb.tenant_stats().in_use_bytes,
            arena.in_use_bytes()
        );
        assert!(sa.ledger().written_bits > 0.0);
        let ba = sa.take(TensorId::act(0)).unwrap();
        for (&v, &b) in va.iter().zip(&ba) {
            assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
        }
        let bb = sb.take(TensorId::act(0)).unwrap();
        for (&v, &b) in vb.iter().zip(&bb) {
            assert_eq!(meta.quantized(v).to_bits(), b.to_bits());
        }
        assert_eq!(arena.in_use_bytes(), 0);
        assert_eq!(sa.failures() + sb.failures(), 0);
    }

    #[test]
    fn take_deferred_then_put_same_id_does_not_race() {
        // The double-buffer ordering: remove step N-1's entry via
        // take_deferred, immediately put step N's tensor under the same
        // id, then flush — the deferred restore must return step N-1's
        // values and the store must hold step N's.
        let stash = small_stash(CodecKind::Gecko);
        let meta = ContainerMeta::new(Container::Fp32, 6);
        let old = vec![1.0f32; 3000];
        let new = vec![2.0f32; 3000];
        stash.put(TensorId::act(0), old.clone(), meta);
        stash.flush();
        let ticket = stash.take_deferred(&[TensorId::act(0)]);
        stash.put(TensorId::act(0), new.clone(), meta);
        stash.flush();
        let restored = ticket.collect();
        let back = restored[0].as_ref().expect("deferred restore present");
        assert!(back.iter().all(|&v| v == 1.0));
        let now = stash.get(TensorId::act(0)).unwrap();
        assert!(now.iter().all(|&v| v == 2.0));
        stash.discard(TensorId::act(0));
        assert_eq!(stash.failures(), 0);
    }
}
