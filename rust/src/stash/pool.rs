//! Bounded multi-threaded worker pool for stash encode/decode jobs.
//!
//! The submit queue is a `sync_channel`, so a producer that outruns the
//! workers blocks instead of buffering unbounded *uncompressed* tensors —
//! the back-pressure that keeps the stash's own memory footprint bounded
//! (the entire point of stashing compressed).  `wait_idle` is the step
//! barrier: the trainer submits every post-forward tensor, then waits once
//! before the backward needs them.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of pool work (encode or decode closure).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct StashPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    failed: Arc<AtomicUsize>,
}

impl StashPool {
    /// `threads = 0` uses the machine's available parallelism;
    /// `queue_depth = 0` defaults to twice the thread count.
    pub fn new(threads: usize, queue_depth: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            threads
        };
        let queue_depth = if queue_depth == 0 {
            2 * threads
        } else {
            queue_depth
        };
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let failed = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let failed = Arc::clone(&failed);
                std::thread::spawn(move || worker_loop(&rx, &pending, &failed))
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            pending,
            failed,
        }
    }

    /// Submit a job; blocks while the queue is full (back-pressure).
    pub fn submit(&self, job: Job) {
        let depth = {
            let (lock, _) = &*self.pending;
            let mut p = lock.lock().unwrap();
            *p += 1;
            *p
        };
        crate::obs::metrics::STASH_QUEUE_PEAK.record_max(depth as u64);
        // flight recorder: queue depth over time (no-op unless tracing)
        crate::obs::timeseries::record("stash_queue_depth", depth as f64);
        let t0 = std::time::Instant::now();
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(job)
            .expect("worker threads alive");
        // time blocked on the bounded queue = encode back-pressure
        crate::obs::metrics::STASH_SUBMIT_WAIT_US.record_duration(t0.elapsed());
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Jobs that panicked (a failed job never blocks [`wait_idle`]).
    pub fn failures(&self) -> usize {
        self.failed.load(Ordering::SeqCst)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    pending: &(Mutex<usize>, Condvar),
    failed: &AtomicUsize,
) {
    loop {
        // Hold the receiver lock only for the dequeue, not the job.
        let job = match rx.lock().unwrap().recv() {
            Ok(job) => job,
            Err(_) => return, // sender dropped: shutdown
        };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            failed.fetch_add(1, Ordering::SeqCst);
        }
        let (lock, cv) = pending;
        *lock.lock().unwrap() -= 1;
        cv.notify_all();
    }
}

impl Drop for StashPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs_and_waits() {
        let pool = StashPool::new(4, 2);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(Box::new(move || {
                sum.fetch_add(i, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
        assert_eq!(pool.failures(), 0);
    }

    #[test]
    fn bounded_queue_applies_backpressure_without_deadlock() {
        let pool = StashPool::new(1, 1);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let hits = Arc::clone(&hits);
            pool.submit(Box::new(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                hits.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn panicked_job_counts_and_does_not_wedge() {
        let pool = StashPool::new(2, 4);
        pool.submit(Box::new(|| panic!("boom")));
        pool.submit(Box::new(|| {}));
        pool.wait_idle();
        assert_eq!(pool.failures(), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = StashPool::new(3, 2);
        pool.submit(Box::new(|| {}));
        drop(pool); // must not hang
    }
}
