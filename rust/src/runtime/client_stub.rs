//! Manifest-only stand-in for the PJRT runtime, used when the crate is
//! built without the `pjrt` feature (the `xla` bindings are not available
//! in the offline build environment).  Manifests still load, so everything
//! that only needs model geometry — the trace models, the stash sweep, the
//! footprint ledgers — works; executing a compiled step reports the
//! missing backend instead.

use super::manifest::Manifest;
use super::tensor::HostTensor;
use anyhow::{anyhow, Result};
use std::path::Path;

/// API-compatible shell of [`client::Runtime`](crate::runtime) holding only
/// the manifest.
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    /// Load `dir/manifest.json`; no artifacts are compiled in this build.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { manifest })
    }

    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".into()
    }

    /// Always fails: there is no backend to execute against.
    pub fn call(&self, name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Err(anyhow!(
            "cannot execute '{name}': built without the `pjrt` feature (the \
             xla bindings are unavailable offline); trace-model and stash \
             commands still work"
        ))
    }
}
