//! PJRT runtime: load AOT HLO-text artifacts (see `python/compile/aot.py`)
//! and execute them from the request path.  Python never runs here.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use tensor::{Data, HostTensor};
