//! Host-side tensors marshalled into / out of PJRT literals.

use super::manifest::{DType, TensorSpec};
use anyhow::{anyhow, Result};

/// A host tensor: shape + typed storage.  All request-path state (model
/// parameters, optimizer state, batches) lives in these between steps.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape: shape.to_vec(),
            data: Data::F32(data),
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape: shape.to_vec(),
            data: Data::I32(data),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::i32(&[], vec![v])
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            DType::F32 => Self::f32(&spec.shape, vec![0.0; spec.elems()]),
            DType::I32 => Self::i32(&spec.shape, vec![0; spec.elems()]),
        }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// First element as f64 (for scalar outputs).
    pub fn item(&self) -> Result<f64> {
        match &self.data {
            Data::F32(v) => v.first().map(|&x| x as f64),
            Data::I32(v) => v.first().map(|&x| x as f64),
        }
        .ok_or_else(|| anyhow!("empty tensor"))
    }

    /// Validate against a manifest spec (shape + dtype).
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape != spec.shape || self.dtype() != spec.dtype {
            return Err(anyhow!(
                "tensor mismatch for '{}': got {:?} {:?}, want {:?} {:?}",
                spec.name,
                self.shape,
                self.dtype(),
                spec.shape,
                spec.dtype
            ));
        }
        Ok(())
    }

    /// Raw little-endian bytes (for PJRT literal creation).
    pub fn bytes(&self) -> Vec<u8> {
        match &self.data {
            Data::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Data::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_item() {
        let t = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.elems(), 6);
        assert_eq!(t.item().unwrap(), 1.0);
        assert_eq!(t.bytes().len(), 24);
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![4],
            dtype: DType::I32,
        };
        assert!(HostTensor::i32(&[4], vec![0; 4]).check(&spec).is_ok());
        assert!(HostTensor::f32(&[4], vec![0.; 4]).check(&spec).is_err());
        assert!(HostTensor::i32(&[2, 2], vec![0; 4]).check(&spec).is_err());
    }

    #[test]
    fn zeros_from_spec() {
        let spec = TensorSpec {
            name: "m".into(),
            shape: vec![2, 2],
            dtype: DType::F32,
        };
        let t = HostTensor::zeros(&spec);
        assert_eq!(t.as_f32().unwrap(), &[0.0; 4]);
    }
}
