//! PJRT execution: load HLO-text artifacts, compile once, run many times.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* is the
//! interchange format (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
//! protos), computations are lowered with `return_tuple=True` so every
//! execution returns one tuple literal that we decompose against the
//! manifest's output specs.

use super::manifest::{ArtifactSpec, DType, Manifest};
use super::tensor::HostTensor;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled entry point plus its marshaling specs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

/// The PJRT runtime: one CPU client + the compiled artifact table.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: BTreeMap<String, Executable>,
}

fn literal_of(t: &HostTensor) -> Result<xla::Literal> {
    let ty = match t.dtype() {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, &t.bytes())
        .map_err(|e| anyhow!("literal creation: {e:?}"))
}

fn host_of(lit: &xla::Literal, spec: &super::manifest::TensorSpec) -> Result<HostTensor> {
    match spec.dtype {
        DType::F32 => {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output '{}' to_vec f32: {e:?}", spec.name))?;
            Ok(HostTensor::f32(&spec.shape, v))
        }
        DType::I32 => {
            let v = lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("output '{}' to_vec i32: {e:?}", spec.name))?;
            Ok(HostTensor::i32(&spec.shape, v))
        }
    }
}

impl Runtime {
    /// Build a CPU PJRT client and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = BTreeMap::new();
        for (name, spec) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("bad path {:?}", spec.file))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            executables.insert(
                name.clone(),
                Executable {
                    exe,
                    spec: spec.clone(),
                },
            );
        }
        Ok(Runtime {
            client,
            manifest,
            executables,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute `name` with `inputs` (validated against the manifest order);
    /// returns the flat output tensors in manifest order.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name}"))?;
        let spec = &exe.spec;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            t.check(s).with_context(|| format!("calling {name}"))?;
        }

        let literals: Vec<xla::Literal> =
            inputs.iter().map(literal_of).collect::<Result<_>>()?;
        let result = exe
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: {} outputs, {} expected",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(l, s)| host_of(l, s))
            .collect()
    }
}
