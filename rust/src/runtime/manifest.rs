//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: per-artifact ordered input/output tensor specs.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of a marshalled tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }
}

/// One tensor slot in an entry point's flat signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest: model geometry + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub image: Vec<usize>,
    pub num_classes: usize,
    pub layers: Vec<String>,
    pub weight_shapes: Vec<Vec<usize>>,
    pub bias_shapes: Vec<Vec<usize>>,
    pub act_shapes: Vec<Vec<usize>>,
    pub lambda_w: Vec<f64>,
    pub lambda_a: Vec<f64>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn specs(j: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing {key}"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("spec name"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("spec shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: DType::parse(t.get("dtype").and_then(Json::as_str).unwrap_or("f32"))?,
            })
        })
        .collect()
}

fn shapes(j: &Json, key: &str) -> Result<Vec<Vec<usize>>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing {key}"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("bad shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect()
        })
        .collect()
}

fn floats(j: &Json, key: &str) -> Result<Vec<f64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing {key}"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("bad float")))
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`; artifact paths become absolute.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(
                        a.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("artifact file"))?,
                    ),
                    inputs: specs(a, "inputs")?,
                    outputs: specs(a, "outputs")?,
                },
            );
        }

        Ok(Manifest {
            batch: j.get("batch").and_then(Json::as_usize).unwrap_or(64),
            image: j
                .get("image")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            num_classes: j.get("num_classes").and_then(Json::as_usize).unwrap_or(10),
            layers: j
                .get("layers")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|s| s.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            weight_shapes: shapes(&j, "weight_shapes")?,
            bias_shapes: shapes(&j, "bias_shapes")?,
            act_shapes: shapes(&j, "act_shapes")?,
            lambda_w: floats(&j, "lambda_w")?,
            lambda_a: floats(&j, "lambda_a")?,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_manifest_shape() {
        let text = r#"{
 "batch": 64, "image": [16, 16, 3], "num_classes": 10,
 "layers": ["c0", "fc"],
 "weight_shapes": [[3, 3, 3, 16], [32, 10]],
 "bias_shapes": [[16], [10]],
 "act_shapes": [[64, 16, 16, 16], [64, 32]],
 "lambda_w": [0.1, 0.2], "lambda_a": [0.3, 0.4],
 "artifacts": {"eval_step": {"file": "eval_step.hlo.txt",
   "inputs": [{"name": "x", "shape": [64, 16, 16, 3], "dtype": "f32"},
              {"name": "y", "shape": [64], "dtype": "i32"}],
   "outputs": [{"name": "correct", "shape": [], "dtype": "i32"}]}}}"#;
        let dir = std::env::temp_dir().join("sfp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.layers, vec!["c0", "fc"]);
        let a = m.artifact("eval_step").unwrap();
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[0].elems(), 64 * 16 * 16 * 3);
        assert_eq!(a.outputs[0].shape.len(), 0);
        assert!(m.artifact("nope").is_err());
    }
}
