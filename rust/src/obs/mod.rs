//! Observability: structured tracing, metrics, leveled logging, and the
//! live progress readout — one cross-cutting layer shared by the Trainer,
//! the stash, and the lab executor.
//!
//! # Pieces
//!
//! - [`trace`]: `Span`/`Event` RAII tracing into thread-local rings that
//!   flush to a global collector.  Disabled (the default) a span is one
//!   relaxed atomic load and **zero allocation**; enabled it costs two
//!   monotonic clock reads and a ring push.  `--trace out.json` renders
//!   the collected events as Chrome trace-event JSON (Perfetto-loadable):
//!   `{"traceEvents":[{"name","cat","ph":"X","ts","dur","pid","tid",
//!   "args":{"job":…}}],"displayTimeUnit":"ms"}` with timestamps in
//!   microseconds since process start.  Under `--backend process` the
//!   workers ship their spans back as an extra protocol line
//!   (`{"hash":…,"spans":[…],"counters":[…],"events":[…]}`) that the
//!   orchestrator merges into the host timeline, keyed by job hash.
//! - [`metrics`]: lock-free counters and log₂-bucket latency histograms
//!   (p50/p99) on the hot paths — cache lookups, steals, worker idle
//!   time, per-codec encode/decode, arena pin-wait / spill fault / evict
//!   stalls, restore latency per tier.  `metrics.json` (a flat
//!   Prometheus-style snapshot) lands next to `lab_manifest.json`.
//! - [`timeseries`]: the flight recorder's sampled gauges — resident vs.
//!   spill stash bytes, queue depth, cache hit ratio, worker utilization
//!   — rendered as Chrome *counter tracks* (`"ph":"C"`) in the same
//!   trace document and exported as `timeseries.json`.
//! - [`events`]: the flight recorder's structured adaptation-event
//!   stream — every `BitPolicy` bitlength change with its triggering
//!   signal, plus stash eviction storms / fault bursts — always on
//!   (not gated by `--trace`), serialized as `events.jsonl` and replayed
//!   by `repro inspect` and the footprint figures.
//! - [`log`]: the one leveled sink every CLI print goes through
//!   (`--quiet` / `-v`), via the crate-root [`oinfo!`](crate::oinfo),
//!   [`overbose!`](crate::overbose) and [`oerror!`](crate::oerror)
//!   macros.
//! - [`progress`]: a single-line live jobs/utilization readout on stderr
//!   while a grid runs (TTY only, never in CI logs).  Log emissions
//!   clear the live line first so errors never interleave with it.
//!
//! # Invariant: observability never perturbs artifact bytes
//!
//! Job bodies never print and never time themselves; spans and metrics
//! live strictly *outside* `execute_spec`, latencies are recorded only
//! into process-global sinks, and nothing observability-derived is ever
//! written into the content-addressed cache.  Manifests and cached
//! artifacts are fingerprint-identical with and without `--trace` (and
//! across serial / in-process / process backends) — CI diffs the
//! fingerprints to prove it.  The one sanctioned path from recorder to
//! artifact is the Trainer's *thread-local* event capture
//! ([`events::capture_begin`]): it sees exactly the events the job's own
//! thread emitted, in program order, so replayed figures stay
//! byte-identical across backends while the racy global sinks feed only
//! side files (`events.jsonl`, `timeseries.json`, the trace).

pub mod events;
pub mod log;
pub mod metrics;
pub mod progress;
pub mod timeseries;
pub mod trace;

pub use events::AdaptEvent;
pub use log::Level;
pub use progress::ProgressLine;
pub use timeseries::{CounterSample, LabSampler};
pub use trace::{span, span_with, Event, Span};

use std::sync::atomic::{AtomicBool, Ordering};

/// Switches resolved from the CLI (`--trace`, `--quiet`, `-v`) and the
/// `SFP_TRACE` environment variable.
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Collect spans (metrics counters are always on — they are a few
    /// relaxed atomics against ms-scale codec work).
    pub tracing: bool,
    /// CLI log verbosity.
    pub level: Level,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            tracing: false,
            level: Level::Normal,
        }
    }
}

/// Master tracing switch: one relaxed load on the disabled fast path.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Apply a config (normally once, at CLI startup).
pub fn init(cfg: &ObsConfig) {
    log::set_level(cfg.level);
    set_enabled(cfg.tracing);
}

/// Is span collection on?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip span collection at runtime (the worker loop enables it when the
/// orchestrator sends a traced request).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Serializes tests that touch the process-global obs state (the enabled
/// flag, the trace sink, the log level) — tests run concurrently, and two
/// tests draining the sink would race.  Tests additionally tag their
/// spans with a unique `cat` and filter on it, so events leaked from
/// non-obs tests can't confuse assertions.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}
