//! Observability: structured tracing, metrics, leveled logging, and the
//! live progress readout — one cross-cutting layer shared by the Trainer,
//! the stash, and the lab executor.
//!
//! # Pieces
//!
//! - [`trace`]: `Span`/`Event` RAII tracing into thread-local rings that
//!   flush to a global collector.  Disabled (the default) a span is one
//!   relaxed atomic load and **zero allocation**; enabled it costs two
//!   monotonic clock reads and a ring push.  `--trace out.json` renders
//!   the collected events as Chrome trace-event JSON (Perfetto-loadable):
//!   `{"traceEvents":[{"name","cat","ph":"X","ts","dur","pid","tid",
//!   "args":{"job":…}}],"displayTimeUnit":"ms"}` with timestamps in
//!   microseconds since process start.  Under `--backend process` the
//!   workers ship their spans back as an extra protocol line
//!   (`{"hash":…,"spans":[…]}`) that the orchestrator merges into the
//!   host timeline, keyed by job hash.
//! - [`metrics`]: lock-free counters and log₂-bucket latency histograms
//!   (p50/p99) on the hot paths — cache lookups, steals, worker idle
//!   time, per-codec encode/decode, arena pin-wait / spill fault / evict
//!   stalls, restore latency per tier.  `metrics.json` (a flat
//!   Prometheus-style snapshot) lands next to `lab_manifest.json`.
//! - [`log`]: the one leveled sink every CLI print goes through
//!   (`--quiet` / `-v`), via the crate-root [`oinfo!`](crate::oinfo),
//!   [`overbose!`](crate::overbose) and [`oerror!`](crate::oerror)
//!   macros.
//! - [`progress`]: a single-line live jobs/utilization readout on stderr
//!   while a grid runs (TTY only, never in CI logs).
//!
//! # Invariant: observability never perturbs artifact bytes
//!
//! Job bodies never print and never time themselves; spans and metrics
//! live strictly *outside* `execute_spec`, latencies are recorded only
//! into process-global sinks, and nothing observability-derived is ever
//! written into the content-addressed cache.  Manifests and cached
//! artifacts are fingerprint-identical with and without `--trace` (and
//! across serial / in-process / process backends) — CI diffs the
//! fingerprints to prove it.

pub mod log;
pub mod metrics;
pub mod progress;
pub mod trace;

pub use log::Level;
pub use progress::ProgressLine;
pub use trace::{span, span_with, Event, Span};

use std::sync::atomic::{AtomicBool, Ordering};

/// Switches resolved from the CLI (`--trace`, `--quiet`, `-v`) and the
/// `SFP_TRACE` environment variable.
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Collect spans (metrics counters are always on — they are a few
    /// relaxed atomics against ms-scale codec work).
    pub tracing: bool,
    /// CLI log verbosity.
    pub level: Level,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            tracing: false,
            level: Level::Normal,
        }
    }
}

/// Master tracing switch: one relaxed load on the disabled fast path.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Apply a config (normally once, at CLI startup).
pub fn init(cfg: &ObsConfig) {
    log::set_level(cfg.level);
    set_enabled(cfg.tracing);
}

/// Is span collection on?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip span collection at runtime (the worker loop enables it when the
/// orchestrator sends a traced request).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Serializes tests that touch the process-global obs state (the enabled
/// flag, the trace sink, the log level) — tests run concurrently, and two
/// tests draining the sink would race.  Tests additionally tag their
/// spans with a unique `cat` and filter on it, so events leaked from
/// non-obs tests can't confuse assertions.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}
