//! Live single-line grid progress/utilization readout on stderr.
//!
//! A background thread repaints `\r[lab] D/N jobs  R running  util P%
//! F failed` every 200 ms while a grid runs, reading only the global
//! [`crate::obs::metrics`] counters — it touches nothing on the job
//! path.  The line is drawn only when stderr is a TTY (never into CI
//! logs or redirected files) and the log level is at least Normal;
//! otherwise [`ProgressLine::start`] is an inert no-op handle.
//!
//! The log sink calls [`clear_for_emit`] before every `oinfo!` /
//! `oerror!` line, which wipes the live readout (under the shared paint
//! lock) so emitted output — job-failure errors in particular — never
//! interleaves with it; the next 200 ms tick repaints.

use super::log;
use super::metrics;
use std::io::{IsTerminal, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TICK: Duration = Duration::from_millis(200);

/// Paint state shared with the log sink: `true` while the live line is
/// currently on screen.  The lock also serializes paints against log
/// emissions so a wipe can never tear a half-painted line.
static PAINTED: Mutex<bool> = Mutex::new(false);

/// Wipe the live readout if it is on screen — called by the log sink
/// right before any line is printed.  The repaint thread restores the
/// readout on its next tick.
pub(crate) fn clear_for_emit() {
    if let Ok(mut painted) = PAINTED.lock() {
        if *painted {
            eprint!("\r{:76}\r", "");
            let _ = std::io::stderr().flush();
            *painted = false;
        }
    }
}

/// RAII handle: starts the repaint thread, stops + clears the line on
/// drop.
pub struct ProgressLine {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ProgressLine {
    /// Begin a readout over `total` jobs on `workers` executor threads.
    pub fn start(total: usize, workers: usize) -> ProgressLine {
        let stop = Arc::new(AtomicBool::new(false));
        if !std::io::stderr().is_terminal() || !log::emits_info() {
            return ProgressLine { stop, handle: None };
        }
        // Counters are process-global and survive earlier runs in the
        // same process; render deltas against this baseline.
        let done0 = metrics::JOBS_DONE.get();
        let started0 = metrics::JOBS_STARTED.get();
        let failed0 = metrics::JOBS_FAILED.get();
        let idle0 = metrics::EXEC_IDLE_US.get();
        let t0 = Instant::now();
        let flag = Arc::clone(&stop);
        let workers = workers.max(1);
        let handle = std::thread::spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                let done = metrics::JOBS_DONE.get() - done0;
                let started = metrics::JOBS_STARTED.get() - started0;
                let failed = metrics::JOBS_FAILED.get() - failed0;
                let idle_us = metrics::EXEC_IDLE_US.get() - idle0;
                let elapsed_us = t0.elapsed().as_micros().max(1) as u64;
                let capacity = (workers as u64 * elapsed_us) as f64;
                let util = (1.0 - idle_us as f64 / capacity).clamp(0.0, 1.0);
                let running = started.saturating_sub(done);
                if let Ok(mut painted) = PAINTED.lock() {
                    eprint!(
                        "\r[lab] {done}/{total} jobs  {running} running  util {:3.0}%  {failed} failed ",
                        util * 100.0
                    );
                    let _ = std::io::stderr().flush();
                    *painted = true;
                }
                std::thread::sleep(TICK);
            }
            // wipe the line so the final summary starts on a clean row
            clear_for_emit();
        });
        ProgressLine {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for ProgressLine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
