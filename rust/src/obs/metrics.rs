//! Lock-free typed counters and log₂-bucket latency histograms for the
//! hot paths, plus the `metrics.json` Prometheus-style snapshot.
//!
//! Everything here is a fixed named static — no registry, no lock.  The
//! recording cost is a handful of relaxed atomic ops against ms-scale
//! codec/executor work, so metrics stay on even when span tracing is
//! disabled.  Latencies are **process-global** observations: they feed
//! `metrics.json` and the surfaced sweep summaries, never the
//! content-addressed artifacts (see the module docs of [`crate::obs`]).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// A monotonically-increasing (or peak-tracking) counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Raise the value to `n` if larger (peak gauges).
    pub fn record_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log₂ bucket count: bucket `i` holds durations in `[2^(i-1), 2^i)` µs
/// (bucket 0 = sub-µs), so 44 buckets span sub-µs to ~2.4 hours.
pub const HIST_BUCKETS: usize = 44;

/// A lock-free latency histogram with power-of-two µs buckets.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

fn bucket_of(us: u64) -> usize {
    if us == 0 {
        0
    } else {
        (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Representative (upper-bound) value of bucket `i` in µs.
fn bucket_hi(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i) - 1
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Point-in-time copy of the bucket counts.
    pub fn snapshot(&self) -> HistBuckets {
        let mut counts = [0u64; HIST_BUCKETS];
        for (out, b) in counts.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        HistBuckets {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
        }
    }

    pub fn summary(&self) -> HistSummary {
        self.snapshot().summary()
    }
}

/// A snapshot of a [`Histogram`]'s buckets — subtractable, so per-epoch
/// deltas come from two cuts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistBuckets {
    pub counts: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum_us: u64,
}

impl Default for HistBuckets {
    fn default() -> Self {
        HistBuckets {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum_us: 0,
        }
    }
}

impl HistBuckets {
    /// The observations recorded between `last` and `self`.
    pub fn delta(&self, last: &HistBuckets) -> HistBuckets {
        let mut counts = [0u64; HIST_BUCKETS];
        for (i, out) in counts.iter_mut().enumerate() {
            *out = self.counts[i].saturating_sub(last.counts[i]);
        }
        HistBuckets {
            counts,
            count: self.count.saturating_sub(last.count),
            sum_us: self.sum_us.saturating_sub(last.sum_us),
        }
    }

    /// Quantile as the upper bound of the bucket holding rank `q·count`
    /// (log₂ resolution — a p50 of 511 µs means "between 256 µs and
    /// 511 µs").
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_hi(i);
            }
        }
        bucket_hi(HIST_BUCKETS - 1)
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count,
            sum_us: self.sum_us,
            p50_us: self.quantile_us(0.50),
            p99_us: self.quantile_us(0.99),
        }
    }
}

/// Compact p50/p99 digest of a histogram (or of a bucket delta).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub sum_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl HistSummary {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum_us".to_string(), Json::Num(self.sum_us as f64));
        m.insert("p50_us".to_string(), Json::Num(self.p50_us as f64));
        m.insert("p99_us".to_string(), Json::Num(self.p99_us as f64));
        Json::Obj(m)
    }
}

/// Codec axis labels, index-aligned with [`crate::stash::CodecKind::index`].
pub const CODEC_LABELS: [&str; 4] = ["gecko", "sfp", "raw", "js"];

// --- lab executor ---
pub static CACHE_HITS: Counter = Counter::new();
pub static CACHE_MISSES: Counter = Counter::new();
pub static CACHE_LOOKUP_US: Histogram = Histogram::new();
pub static STEALS: Counter = Counter::new();
/// Accumulated µs executor workers spent parked waiting for work.
pub static EXEC_IDLE_US: Counter = Counter::new();
pub static JOBS_STARTED: Counter = Counter::new();
pub static JOBS_DONE: Counter = Counter::new();
pub static JOBS_EXECUTED: Counter = Counter::new();
pub static JOBS_CACHED: Counter = Counter::new();
pub static JOBS_FAILED: Counter = Counter::new();

// --- stash pool / arena ---
/// Peak submit-queue depth (jobs pending) over the process lifetime.
pub static STASH_QUEUE_PEAK: Counter = Counter::new();
/// Back-pressure: time `submit` blocked on the bounded queue.
pub static STASH_SUBMIT_WAIT_US: Histogram = Histogram::new();
/// Arena pin calls blocked on a chunk being faulted in by another thread.
pub static PIN_WAIT_US: Histogram = Histogram::new();
/// Bounded pin waits that ended with the chunk still in flight (timeout
/// or wake-and-retry) — the pin starvation / fairness observability knob.
pub static PIN_STALL_RETRIES: Counter = Counter::new();
/// Demand faults: spill-file read latency per faulted batch.
pub static FAULT_US: Histogram = Histogram::new();
/// Eviction batches: spill-file write latency per planned batch.
pub static EVICT_US: Histogram = Histogram::new();
/// Spill-tier pread syscalls (run-granular: adjacent chunks share one).
pub static SPILL_PREAD_CALLS: Counter = Counter::new();
/// Spill-tier pwrite syscalls (run-granular: adjacent chunks share one).
pub static SPILL_PWRITE_CALLS: Counter = Counter::new();
/// Chunks faulted spill → DRAM across all arenas.
pub static SPILL_CHUNKS_READ: Counter = Counter::new();
/// Chunks evicted DRAM → spill across all arenas.
pub static SPILL_CHUNKS_WRITTEN: Counter = Counter::new();

// --- codecs ---
pub static ENCODE_US: [Histogram; 4] = [const { Histogram::new() }; 4];
pub static DECODE_US: [Histogram; 4] = [const { Histogram::new() }; 4];
/// f32 payload bytes handed to each codec's encode path (input side —
/// with the matching `_US` histogram's `sum_us` this yields GB/s).
pub static ENCODE_BYTES: [Counter; 4] = [const { Counter::new() }; 4];
/// f32 payload bytes produced by each codec's decode path.
pub static DECODE_BYTES: [Counter; 4] = [const { Counter::new() }; 4];

// --- restore tiers (global aggregate; the per-stash ledger keeps its own) ---
/// Restore (pin+decode) latency when every chunk was DRAM-resident.
pub static RESTORE_DRAM_US: Histogram = Histogram::new();
/// Restore latency when at least one chunk faulted back from spill.
pub static RESTORE_FAULT_US: Histogram = Histogram::new();

fn per_codec_json(hists: &[Histogram; 4]) -> Json {
    let mut m = BTreeMap::new();
    for (h, label) in hists.iter().zip(CODEC_LABELS) {
        m.insert(label.to_string(), h.summary().to_json());
    }
    Json::Obj(m)
}

fn per_codec_bytes(counters: &[Counter; 4]) -> Json {
    let mut m = BTreeMap::new();
    for (c, label) in counters.iter().zip(CODEC_LABELS) {
        m.insert(label.to_string(), Json::Num(c.get() as f64));
    }
    Json::Obj(m)
}

/// Flat Prometheus-style snapshot of every metric.
pub fn snapshot() -> Json {
    let mut m = BTreeMap::new();
    let num = |v: u64| Json::Num(v as f64);
    m.insert("lab_cache_hits_total".to_string(), num(CACHE_HITS.get()));
    m.insert("lab_cache_misses_total".to_string(), num(CACHE_MISSES.get()));
    m.insert(
        "lab_cache_lookup_us".to_string(),
        CACHE_LOOKUP_US.summary().to_json(),
    );
    m.insert("lab_steals_total".to_string(), num(STEALS.get()));
    m.insert("lab_worker_idle_us_total".to_string(), num(EXEC_IDLE_US.get()));
    m.insert("lab_jobs_started_total".to_string(), num(JOBS_STARTED.get()));
    m.insert("lab_jobs_done_total".to_string(), num(JOBS_DONE.get()));
    m.insert(
        "lab_jobs_executed_total".to_string(),
        num(JOBS_EXECUTED.get()),
    );
    m.insert("lab_jobs_cached_total".to_string(), num(JOBS_CACHED.get()));
    m.insert("lab_jobs_failed_total".to_string(), num(JOBS_FAILED.get()));
    m.insert(
        "stash_queue_depth_peak".to_string(),
        num(STASH_QUEUE_PEAK.get()),
    );
    m.insert(
        "stash_submit_wait_us".to_string(),
        STASH_SUBMIT_WAIT_US.summary().to_json(),
    );
    m.insert("stash_pin_wait_us".to_string(), PIN_WAIT_US.summary().to_json());
    m.insert(
        "stash_pin_stall_retries_total".to_string(),
        num(PIN_STALL_RETRIES.get()),
    );
    m.insert("stash_fault_us".to_string(), FAULT_US.summary().to_json());
    m.insert("stash_evict_us".to_string(), EVICT_US.summary().to_json());
    m.insert(
        "stash_spill_pread_calls_total".to_string(),
        num(SPILL_PREAD_CALLS.get()),
    );
    m.insert(
        "stash_spill_pwrite_calls_total".to_string(),
        num(SPILL_PWRITE_CALLS.get()),
    );
    m.insert(
        "stash_spill_chunks_read_total".to_string(),
        num(SPILL_CHUNKS_READ.get()),
    );
    m.insert(
        "stash_spill_chunks_written_total".to_string(),
        num(SPILL_CHUNKS_WRITTEN.get()),
    );
    m.insert("stash_encode_us".to_string(), per_codec_json(&ENCODE_US));
    m.insert("stash_decode_us".to_string(), per_codec_json(&DECODE_US));
    m.insert(
        "stash_encode_bytes_total".to_string(),
        per_codec_bytes(&ENCODE_BYTES),
    );
    m.insert(
        "stash_decode_bytes_total".to_string(),
        per_codec_bytes(&DECODE_BYTES),
    );
    m.insert(
        "stash_restore_dram_us".to_string(),
        RESTORE_DRAM_US.summary().to_json(),
    );
    m.insert(
        "stash_restore_fault_us".to_string(),
        RESTORE_FAULT_US.summary().to_json(),
    );
    Json::Obj(m)
}

/// Write the snapshot to `path` (normally `metrics.json` next to
/// `lab_manifest.json`).
pub fn write_snapshot(path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, snapshot().to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_add_and_track_peaks() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.record_max(3);
        assert_eq!(c.get(), 5, "peak never regresses");
        c.record_max(9);
        assert_eq!(c.get(), 9);
    }

    #[test]
    fn histogram_quantiles_land_in_log2_buckets() {
        let h = Histogram::new();
        for us in [1u64, 2, 3, 100, 100, 100, 100, 10_000] {
            h.record(us);
        }
        let s = h.summary();
        assert_eq!(s.count, 8);
        assert_eq!(s.sum_us, 1 + 2 + 3 + 400 + 10_000);
        // the median observation (100 µs) sits in bucket [64, 127]
        assert_eq!(s.p50_us, 127);
        // the p99 observation (10 ms) sits in bucket [8192, 16383]
        assert_eq!(s.p99_us, 16383);
        // empty histograms answer zero, not a panic
        assert_eq!(Histogram::new().summary(), HistSummary::default());
    }

    #[test]
    fn bucket_deltas_summarize_only_new_observations() {
        let h = Histogram::new();
        h.record(10);
        let first = h.snapshot();
        h.record(1000);
        h.record(1000);
        let d = h.snapshot().delta(&first);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum_us, 2000);
        let s = d.summary();
        assert_eq!(s.p50_us, 1023);
        assert_eq!(s.p99_us, 1023);
    }

    #[test]
    fn snapshot_is_valid_flat_json() {
        let doc = snapshot().to_string();
        let j = Json::parse(&doc).unwrap();
        assert!(j.get("lab_cache_hits_total").is_some());
        let enc = j.get("stash_encode_us").unwrap();
        for label in CODEC_LABELS {
            assert!(enc.get(label).unwrap().get("p99_us").is_some());
        }
    }

    #[test]
    fn summary_json_shape() {
        let s = HistSummary {
            count: 3,
            sum_us: 30,
            p50_us: 15,
            p99_us: 15,
        };
        let j = s.to_json();
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(3.0));
        assert_eq!(j.get("p50_us").and_then(Json::as_f64), Some(15.0));
    }
}
