//! Sampled gauges and stamped scalar series — the "how much, over time"
//! half of the flight recorder, rendered as Chrome-trace *counter
//! tracks* (`"ph":"C"`) alongside the span timeline.
//!
//! Producers call [`record`] with a track name and the current value;
//! samples land in a global sink only while tracing is on (one relaxed
//! load on the disabled path, like spans).  Track names follow
//! `group.series` — samples with the same group render as one Chrome
//! counter track with one line per series, so `stash_bytes.resident`
//! and `stash_bytes.spill` stack in a single lane.  A bare name renders
//! as a single-series track named `value`.
//!
//! Push-style samples come from the stash (`resident`/`spill` bytes on
//! every put and flush, queue depth on every submit); the pull-style
//! lab gauges (cache hit ratio, worker utilization, jobs running) are
//! polled by a [`LabSampler`] background thread while a grid runs.

use crate::util::json::Json;
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One timestamped scalar sample on a named counter track.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSample {
    /// `group.series` (or a bare group name).
    pub track: Cow<'static, str>,
    /// µs since the process trace epoch (shared with spans).
    pub ts_us: u64,
    pub value: f64,
    pub pid: u32,
}

impl CounterSample {
    /// Split the track name into (chrome counter name, series key).
    pub fn name_series(&self) -> (&str, &str) {
        match self.track.split_once('.') {
            Some((name, series)) => (name, series),
            None => (self.track.as_ref(), "value"),
        }
    }
}

static SINK: Mutex<Vec<CounterSample>> = Mutex::new(Vec::new());

/// Record one sample.  No-op (one relaxed load) unless tracing is on —
/// counter tracks only exist inside a Chrome trace, so sampling without
/// `--trace` would buffer unread data forever.
#[inline]
pub fn record(track: &'static str, value: f64) {
    if !super::enabled() {
        return;
    }
    push(CounterSample {
        track: Cow::Borrowed(track),
        ts_us: super::trace::now_us(),
        value,
        pid: std::process::id(),
    });
}

/// [`record`] with an owned track name — for tracks only known at run
/// time, like the per-tenant serve gauges (`serve_bytes.<lease label>`).
/// Callers should gate the name construction on [`super::enabled`] so the
/// disabled path stays allocation-free.
#[inline]
pub fn record_owned(track: String, value: f64) {
    if !super::enabled() {
        return;
    }
    push(CounterSample {
        track: Cow::Owned(track),
        ts_us: super::trace::now_us(),
        value,
        pid: std::process::id(),
    });
}

fn push(s: CounterSample) {
    if let Ok(mut sink) = SINK.lock() {
        sink.push(s);
    }
}

/// Append pre-built samples (the cross-process merge path).
pub fn absorb(samples: Vec<CounterSample>) {
    if samples.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        sink.extend(samples);
    }
}

/// Drain the global sink.
pub fn take_samples() -> Vec<CounterSample> {
    match SINK.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

/// One sample as a flat JSON object — the shape shared by the
/// `timeseries.json` export and the worker batch protocol.
pub fn sample_json(s: &CounterSample) -> Json {
    let mut m = BTreeMap::new();
    m.insert("track".to_string(), Json::Str(s.track.to_string()));
    m.insert("ts".to_string(), Json::Num(s.ts_us as f64));
    m.insert("value".to_string(), Json::Num(s.value));
    m.insert("pid".to_string(), Json::Num(s.pid as f64));
    Json::Obj(m)
}

/// Inverse of [`sample_json`].
pub fn sample_from_json(j: &Json) -> Option<CounterSample> {
    Some(CounterSample {
        track: Cow::Owned(j.get("track")?.as_str()?.to_string()),
        ts_us: j.get("ts")?.as_f64()? as u64,
        value: j.get("value")?.as_f64()?,
        pid: j.get("pid")?.as_f64()? as u32,
    })
}

/// Write samples as a `timeseries.json` array at `path` (parent created).
pub fn write_json(path: &Path, samples: &[CounterSample]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let arr: Vec<Json> = samples.iter().map(sample_json).collect();
    std::fs::write(path, Json::Arr(arr).to_string())?;
    Ok(())
}

/// Polling interval for the lab gauges.
const SAMPLE_TICK: Duration = Duration::from_millis(50);

/// RAII background sampler for the pull-style lab gauges: cache hit
/// ratio, worker utilization, and jobs in flight.  Inert when tracing
/// is off at start.  Reads only global metrics counters — nothing on
/// the job path.
pub struct LabSampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LabSampler {
    /// Start sampling against `workers` executor threads.
    pub fn start(workers: usize) -> LabSampler {
        let stop = Arc::new(AtomicBool::new(false));
        if !super::enabled() {
            return LabSampler { stop, handle: None };
        }
        use super::metrics;
        let hits0 = metrics::CACHE_HITS.get();
        let misses0 = metrics::CACHE_MISSES.get();
        let done0 = metrics::JOBS_DONE.get();
        let started0 = metrics::JOBS_STARTED.get();
        let idle0 = metrics::EXEC_IDLE_US.get();
        let t0_us = super::trace::now_us();
        let workers = workers.max(1);
        let flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || loop {
            let hits = metrics::CACHE_HITS.get() - hits0;
            let misses = metrics::CACHE_MISSES.get() - misses0;
            let lookups = hits + misses;
            if lookups > 0 {
                record("lab_cache_hit_ratio", hits as f64 / lookups as f64);
            }
            let idle_us = metrics::EXEC_IDLE_US.get() - idle0;
            let elapsed_us = (super::trace::now_us() - t0_us).max(1);
            let capacity = (workers as u64 * elapsed_us) as f64;
            let util = (1.0 - idle_us as f64 / capacity).clamp(0.0, 1.0);
            record("lab_worker_util_pct", util * 100.0);
            let running = (metrics::JOBS_STARTED.get() - started0)
                .saturating_sub(metrics::JOBS_DONE.get() - done0);
            record("lab_jobs_running", running as f64);
            if flag.load(Ordering::Relaxed) {
                return; // final sample taken after stop was requested
            }
            std::thread::sleep(SAMPLE_TICK);
        });
        LabSampler {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for LabSampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_is_gated_on_the_tracing_switch() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_samples();
        record("gate_test.x", 1.0);
        assert!(take_samples().is_empty());
        crate::obs::set_enabled(true);
        record("gate_test.x", 2.0);
        record("gate_test", 3.0);
        crate::obs::set_enabled(false);
        let samples = take_samples();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].name_series(), ("gate_test", "x"));
        assert_eq!(samples[1].name_series(), ("gate_test", "value"));
        assert_eq!(samples[1].value, 3.0);
    }

    #[test]
    fn lab_sampler_emits_gauges_while_running() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(true);
        let _ = take_samples();
        {
            let _s = LabSampler::start(2);
            std::thread::sleep(Duration::from_millis(60));
        }
        crate::obs::set_enabled(false);
        let samples = take_samples();
        let tracks: std::collections::BTreeSet<&str> =
            samples.iter().map(|s| s.track.as_ref()).collect();
        assert!(tracks.contains("lab_worker_util_pct"), "{tracks:?}");
        assert!(tracks.contains("lab_jobs_running"), "{tracks:?}");
        assert!(samples
            .iter()
            .all(|s| s.value.is_finite() && s.pid == std::process::id()));
    }

    #[test]
    fn lab_sampler_is_inert_when_tracing_is_off() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_samples();
        {
            let _s = LabSampler::start(2);
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(take_samples().is_empty());
    }
}
