//! The one leveled CLI log sink.  Job bodies never print (the lab's
//! determinism contract); everything user-facing goes through the
//! crate-root [`oinfo!`](crate::oinfo), [`overbose!`](crate::overbose)
//! and [`oerror!`](crate::oerror) macros, which check the level *before*
//! formatting.  Errors always reach stderr; info lands on stdout unless
//! `--quiet`; verbose lines need `-v`.

use std::sync::atomic::{AtomicU8, Ordering};

/// CLI verbosity (`--quiet` < default < `-v`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Quiet = 0,
    Normal = 1,
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Normal as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Normal,
        _ => Level::Verbose,
    }
}

/// Would an info-level line be emitted?
#[inline]
pub fn emits_info() -> bool {
    LEVEL.load(Ordering::Relaxed) >= Level::Normal as u8
}

/// Would a verbose-level line be emitted?
#[inline]
pub fn emits_verbose() -> bool {
    LEVEL.load(Ordering::Relaxed) >= Level::Verbose as u8
}

/// Emit a pre-formatted info line (macro back end — prefer `oinfo!`).
pub fn info_str(s: &str) {
    if emits_info() {
        super::progress::clear_for_emit();
        println!("{s}");
    }
}

/// Emit a pre-formatted verbose line (macro back end — prefer `overbose!`).
pub fn verbose_str(s: &str) {
    if emits_verbose() {
        super::progress::clear_for_emit();
        println!("{s}");
    }
}

/// Emit an error line on stderr — never suppressed.  Clears the live
/// progress readout first so failures never interleave with it.
pub fn error_str(s: &str) {
    super::progress::clear_for_emit();
    eprintln!("{s}");
}

/// Info-level CLI line (stdout; suppressed by `--quiet`).  The format
/// arguments are only evaluated when the line will be emitted.
#[macro_export]
macro_rules! oinfo {
    ($($arg:tt)*) => {
        if $crate::obs::log::emits_info() {
            $crate::obs::log::info_str(&format!($($arg)*));
        }
    };
}

/// Verbose-level CLI line (stdout; needs `-v`).
#[macro_export]
macro_rules! overbose {
    ($($arg:tt)*) => {
        if $crate::obs::log::emits_verbose() {
            $crate::obs::log::verbose_str(&format!($($arg)*));
        }
    };
}

/// Error line (stderr; never suppressed).
#[macro_export]
macro_rules! oerror {
    ($($arg:tt)*) => {
        $crate::obs::log::error_str(&format!($($arg)*));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_gate_info_and_verbose_but_never_errors() {
        let _g = crate::obs::test_guard();
        set_level(Level::Quiet);
        assert!(!emits_info() && !emits_verbose());
        set_level(Level::Normal);
        assert!(emits_info() && !emits_verbose());
        set_level(Level::Verbose);
        assert!(emits_info() && emits_verbose());
        assert_eq!(level(), Level::Verbose);
        set_level(Level::Normal);
        assert_eq!(level(), Level::Normal);
        assert!(Level::Quiet < Level::Normal && Level::Normal < Level::Verbose);
    }
}
