//! Structured adaptation-event stream — the "what changed and why" half
//! of the flight recorder.
//!
//! Every [`crate::policy::BitPolicy`] emits an [`AdaptEvent`] when a
//! per-layer *stored* bitlength (the integer that actually changes
//! artifact bytes — `ceil(mant)` or the clamped exponent width) crosses
//! to a new value, tagged with the triggering signal (`qm_gradient_step`,
//! `qe_overflow_floor`, `bitwave_loss_ema`, …).  The stash ledger emits
//! pressure events when evictions or faults arrive in bursts.  Events
//! are **always recorded** — unlike spans they are rare (a handful per
//! epoch) and carry the paper's core signal, so they do not hide behind
//! `--trace`.  The stream is serialized as `events.jsonl` next to
//! `lab_manifest.json`, shipped across the worker protocol on the span
//! batch line, and replayed by `repro inspect` and
//! [`crate::report::figures::footprint_over_time`].
//!
//! # Determinism
//!
//! The global sink interleaves events from concurrently running jobs, so
//! nothing read from it may enter a job artifact.  Artifact producers
//! (the Trainer) instead wrap their run in [`capture_begin`] /
//! [`capture_end`]: a thread-local side channel that sees exactly the
//! events emitted on the calling thread, in program order — identical
//! across serial, in-process, and process backends.

use crate::util::json::Json;
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

/// One recorded adaptation decision (or stash pressure episode).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptEvent {
    /// µs since the process trace epoch (shared with spans).
    pub ts_us: u64,
    pub pid: u32,
    /// `"bitlength"` for stored-width policy decisions, `"layout"` for
    /// exponent-layout switches (width ↔ bias window ↔ block-shared),
    /// `"stash_pressure"` for eviction storms / fault bursts.
    pub kind: Cow<'static, str>,
    /// Policy name (`"qm"`, `"qe"`, `"bitwave"`, `"bc"`) or `"stash"`.
    pub source: Cow<'static, str>,
    /// What tripped the change, e.g. `"qm_gradient_step"`,
    /// `"qe_overflow_floor"`, `"bitwave_loss_ema"`, `"eviction_storm"`.
    pub trigger: Cow<'static, str>,
    /// Layer index for per-layer decisions; `None` for network-wide
    /// switches (BitWave) and stash events.
    pub layer: Option<usize>,
    /// `"act"` / `"weight"` for bitlength events.
    pub tensor_class: Option<Cow<'static, str>>,
    /// `"mant"` / `"exp"` for bitlength events.
    pub component: Option<Cow<'static, str>>,
    pub epoch: Option<usize>,
    pub step: Option<usize>,
    /// Old value (stored bits) — or episode count for stash pressure.
    pub from: f64,
    /// New value (stored bits) — or window length in µs for pressure.
    pub to: f64,
    /// Free-form transition label for `"layout"` events (e.g.
    /// `"w8 -> af4b121"` — [`ExponentLayout::label`] strings); `None`
    /// for bitlength/pressure events.
    ///
    /// [`ExponentLayout::label`]: crate::formats::ExponentLayout::label
    pub detail: Option<Cow<'static, str>>,
    /// Job content hash, filled in when the event crossed the worker
    /// protocol (host-side events are keyed by run instead).
    pub arg_job: Option<String>,
    /// Originating tenant / owner label for shared-resource episodes
    /// (stash pressure from a leased arena) — lets `repro inspect`
    /// attribute thrash to the tenant that caused it instead of
    /// reporting it globally.  `None` for single-owner sources.
    pub owner: Option<Cow<'static, str>>,
}

static SINK: Mutex<Vec<AdaptEvent>> = Mutex::new(Vec::new());

thread_local! {
    static CAPTURE: RefCell<Option<Vec<AdaptEvent>>> = const { RefCell::new(None) };
}

/// Record an event: appended to the global sink and, when the calling
/// thread has an active capture, to that capture too.
pub fn record(ev: AdaptEvent) {
    let _ = CAPTURE.try_with(|c| {
        if let Some(buf) = c.borrow_mut().as_mut() {
            buf.push(ev.clone());
        }
    });
    if let Ok(mut sink) = SINK.lock() {
        sink.push(ev);
    }
}

/// Record a per-layer stored-bitlength change.  `layer = None` marks a
/// network-wide switch.
#[allow(clippy::too_many_arguments)]
pub fn bit_change(
    source: &'static str,
    trigger: &'static str,
    tensor_class: &'static str,
    component: &'static str,
    layer: Option<usize>,
    epoch: usize,
    step: usize,
    from: f64,
    to: f64,
) {
    record(AdaptEvent {
        ts_us: super::trace::now_us(),
        pid: std::process::id(),
        kind: Cow::Borrowed("bitlength"),
        source: Cow::Borrowed(source),
        trigger: Cow::Borrowed(trigger),
        layer,
        tensor_class: Some(Cow::Borrowed(tensor_class)),
        component: Some(Cow::Borrowed(component)),
        epoch: Some(epoch),
        step: Some(step),
        from,
        to,
        detail: None,
        arg_job: None,
        owner: None,
    });
}

/// Record a per-layer exponent-layout switch: `from`/`to` carry the
/// stored exponent-field bits (so numeric trajectories keep working) and
/// `detail` the human transition label (`"w8 -> af4b121"`).  `layer =
/// None` marks a network-wide switch.
#[allow(clippy::too_many_arguments)]
pub fn layout_change(
    source: &'static str,
    trigger: &'static str,
    tensor_class: &'static str,
    layer: Option<usize>,
    epoch: usize,
    step: usize,
    from: f64,
    to: f64,
    detail: String,
) {
    record(AdaptEvent {
        ts_us: super::trace::now_us(),
        pid: std::process::id(),
        kind: Cow::Borrowed("layout"),
        source: Cow::Borrowed(source),
        trigger: Cow::Borrowed(trigger),
        layer,
        tensor_class: Some(Cow::Borrowed(tensor_class)),
        component: Some(Cow::Borrowed("exp")),
        epoch: Some(epoch),
        step: Some(step),
        from,
        to,
        detail: Some(Cow::Owned(detail)),
        arg_job: None,
        owner: None,
    });
}

/// Record a stash pressure episode: `count` evictions/faults landed
/// within `window_us`.
pub fn stash_pressure(trigger: &'static str, count: u64, window_us: u64) {
    stash_pressure_for(None, trigger, count, window_us);
}

/// [`stash_pressure`] tagged with the originating tenant/owner label —
/// the multi-tenant arena path, so eviction storms and fault bursts are
/// attributable to the lease that caused them.
pub fn stash_pressure_for(
    owner: Option<Cow<'static, str>>,
    trigger: &'static str,
    count: u64,
    window_us: u64,
) {
    record(AdaptEvent {
        ts_us: super::trace::now_us(),
        pid: std::process::id(),
        kind: Cow::Borrowed("stash_pressure"),
        source: Cow::Borrowed("stash"),
        trigger: Cow::Borrowed(trigger),
        layer: None,
        tensor_class: None,
        component: None,
        epoch: None,
        step: None,
        from: count as f64,
        to: window_us as f64,
        detail: None,
        arg_job: None,
        owner,
    });
}

/// Begin capturing this thread's events (resets any prior capture).
/// Artifact producers call this so their replayed event list is local,
/// ordered, and free of other jobs' interleavings.
pub fn capture_begin() {
    let _ = CAPTURE.try_with(|c| *c.borrow_mut() = Some(Vec::new()));
}

/// End the thread-local capture and return everything recorded on this
/// thread since [`capture_begin`].
pub fn capture_end() -> Vec<AdaptEvent> {
    CAPTURE
        .try_with(|c| c.borrow_mut().take().unwrap_or_default())
        .unwrap_or_default()
}

/// Append pre-built events (the cross-process merge path — bypasses any
/// local capture, which must only see this process's own decisions).
pub fn absorb(events: Vec<AdaptEvent>) {
    if events.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        sink.extend(events);
    }
}

/// Drain the global sink.
pub fn take_events() -> Vec<AdaptEvent> {
    match SINK.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

/// One event as a flat JSON object (one `events.jsonl` line).
pub fn event_json(ev: &AdaptEvent) -> Json {
    let mut m = BTreeMap::new();
    m.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
    m.insert("pid".to_string(), Json::Num(ev.pid as f64));
    m.insert("kind".to_string(), Json::Str(ev.kind.to_string()));
    m.insert("source".to_string(), Json::Str(ev.source.to_string()));
    m.insert("trigger".to_string(), Json::Str(ev.trigger.to_string()));
    if let Some(layer) = ev.layer {
        m.insert("layer".to_string(), Json::Num(layer as f64));
    }
    if let Some(c) = &ev.tensor_class {
        m.insert("class".to_string(), Json::Str(c.to_string()));
    }
    if let Some(c) = &ev.component {
        m.insert("component".to_string(), Json::Str(c.to_string()));
    }
    if let Some(e) = ev.epoch {
        m.insert("epoch".to_string(), Json::Num(e as f64));
    }
    if let Some(s) = ev.step {
        m.insert("step".to_string(), Json::Num(s as f64));
    }
    m.insert("from".to_string(), Json::Num(ev.from));
    m.insert("to".to_string(), Json::Num(ev.to));
    if let Some(d) = &ev.detail {
        m.insert("detail".to_string(), Json::Str(d.to_string()));
    }
    if let Some(job) = &ev.arg_job {
        m.insert("job".to_string(), Json::Str(job.clone()));
    }
    if let Some(o) = &ev.owner {
        m.insert("owner".to_string(), Json::Str(o.to_string()));
    }
    Json::Obj(m)
}

/// Parse one `events.jsonl` line back (inverse of [`event_json`]).
pub fn event_from_json(j: &Json) -> Option<AdaptEvent> {
    let owned = |key: &str| -> Option<Cow<'static, str>> {
        j.get(key)
            .and_then(Json::as_str)
            .map(|s| Cow::Owned(s.to_string()))
    };
    Some(AdaptEvent {
        ts_us: j.get("ts")?.as_f64()? as u64,
        pid: j.get("pid")?.as_f64()? as u32,
        kind: owned("kind")?,
        source: owned("source")?,
        trigger: owned("trigger")?,
        layer: j.get("layer").and_then(Json::as_f64).map(|v| v as usize),
        tensor_class: owned("class"),
        component: owned("component"),
        epoch: j.get("epoch").and_then(Json::as_f64).map(|v| v as usize),
        step: j.get("step").and_then(Json::as_f64).map(|v| v as usize),
        from: j.get("from")?.as_f64()?,
        to: j.get("to")?.as_f64()?,
        detail: owned("detail"),
        arg_job: j
            .get("job")
            .and_then(Json::as_str)
            .map(|s| s.to_string()),
        owner: owned("owner"),
    })
}

/// Serialize events as JSON-lines (one object per line, trailing `\n`
/// when non-empty).
pub fn render_jsonl(events: &[AdaptEvent]) -> String {
    let mut out = String::new();
    for ev in events {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    out
}

/// Parse an `events.jsonl` document (blank lines skipped, bad lines
/// dropped).
pub fn parse_jsonl(text: &str) -> Vec<AdaptEvent> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| Json::parse(l).ok())
        .filter_map(|j| event_from_json(&j))
        .collect()
}

/// Write events as `events.jsonl` at `path` (parent created).
pub fn write_jsonl(path: &Path, events: &[AdaptEvent]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, render_jsonl(events))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_round_trip_through_jsonl() {
        let events = vec![
            AdaptEvent {
                ts_us: 12,
                pid: 7,
                kind: Cow::Borrowed("bitlength"),
                source: Cow::Borrowed("qm"),
                trigger: Cow::Borrowed("qm_gradient_step"),
                layer: Some(3),
                tensor_class: Some(Cow::Borrowed("act")),
                component: Some(Cow::Borrowed("mant")),
                epoch: Some(1),
                step: Some(40),
                from: 8.0,
                to: 6.0,
                detail: None,
                arg_job: Some("cafe".to_string()),
                owner: None,
            },
            AdaptEvent {
                ts_us: 50,
                pid: 7,
                kind: Cow::Borrowed("layout"),
                source: Cow::Borrowed("af"),
                trigger: Cow::Borrowed("af_window_fit"),
                layer: Some(1),
                tensor_class: Some(Cow::Borrowed("act")),
                component: Some(Cow::Borrowed("exp")),
                epoch: Some(2),
                step: Some(61),
                from: 8.0,
                to: 4.0,
                detail: Some(Cow::Borrowed("w8 -> af4b121")),
                arg_job: None,
                owner: None,
            },
            AdaptEvent {
                ts_us: 99,
                pid: 7,
                kind: Cow::Borrowed("stash_pressure"),
                source: Cow::Borrowed("stash"),
                trigger: Cow::Borrowed("eviction_storm"),
                layer: None,
                tensor_class: None,
                component: None,
                epoch: None,
                step: None,
                from: 16.0,
                to: 250_000.0,
                detail: None,
                arg_job: None,
                owner: Some(Cow::Borrowed("serve.t3")),
            },
        ];
        let text = render_jsonl(&events);
        assert_eq!(text.lines().count(), 3, "one object per line");
        assert_eq!(parse_jsonl(&text), events);
        assert_eq!(parse_jsonl(""), Vec::<AdaptEvent>::new());
    }

    #[test]
    fn capture_sees_only_this_threads_events_in_order() {
        // The events sink is always-on and unguarded tests may emit
        // concurrently, so global-sink assertions filter by this test's
        // unique source tags; the guard serializes against other
        // sink-draining obs tests.
        let _g = crate::obs::test_guard();
        capture_begin();
        bit_change("cap-test-qm", "qm_gradient_step", "act", "mant", Some(0), 0, 1, 8.0, 7.0);
        std::thread::spawn(|| {
            bit_change("cap-test-qe", "qe_gradient_step", "act", "exp", Some(1), 0, 2, 8.0, 5.0);
        })
        .join()
        .unwrap();
        bit_change("cap-test-qm", "qm_gradient_step", "act", "mant", Some(0), 0, 3, 7.0, 6.0);
        let captured = capture_end();
        assert_eq!(captured.len(), 2, "other threads stay out of the capture");
        assert!(captured.iter().all(|e| e.source == "cap-test-qm"));
        assert!(captured[0].step < captured[1].step, "program order");
        // the global sink saw all three (ours filtered from the drain)
        let ours: Vec<AdaptEvent> = take_events()
            .into_iter()
            .filter(|e| e.source.starts_with("cap-test-"))
            .collect();
        assert_eq!(ours.len(), 3);
        // and a second capture_end without begin is empty
        assert!(capture_end().is_empty());
    }
}
