//! Span/event tracing: RAII spans into thread-local rings, a global
//! collector, Chrome trace-event export, and cross-process batch merge.
//!
//! A [`Span`] is recorded on drop as one complete event (`"ph":"X"`).
//! When tracing is disabled ([`crate::obs::enabled`] is false) a span is
//! a `None` — constructing and dropping it performs no allocation and no
//! clock read.  Enabled, events land in a per-thread ring that flushes
//! to the global sink every [`RING_CAPACITY`] events and on thread exit,
//! so hot paths never contend on the sink lock.
//!
//! Timestamps are microseconds from a process-wide monotonic epoch
//! (first use), which keeps them positive, small, and Perfetto-friendly.
//! Worker processes have their own epoch; [`absorb_remote_batch`] shifts
//! a worker batch so its latest span end lands at the host-side receive
//! time, which is the best alignment available without a shared clock.
//!
//! Beyond spans, the Chrome export interleaves *counter tracks*
//! (`"ph":"C"`) from [`super::timeseries`] samples, and the worker batch
//! line carries the other flight-recorder streams too:
//! `{"hash":…,"spans":[…],"counters":[…],"events":[…]}` — all three
//! shifted onto the host clock on absorb.

use super::events::AdaptEvent;
use super::timeseries::CounterSample;
use crate::util::json::Json;
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Events buffered per thread before a flush to the global sink.
pub const RING_CAPACITY: usize = 128;

/// One completed span, shaped for the Chrome trace-event format.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: Cow<'static, str>,
    pub cat: Cow<'static, str>,
    /// Start, µs since the process trace epoch.
    pub ts_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    pub pid: u32,
    pub tid: u64,
    /// Job content hash, when the span belongs to a lab job.
    pub arg_job: Option<String>,
}

static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

struct Ring {
    buf: Vec<Event>,
}

impl Drop for Ring {
    fn drop(&mut self) {
        flush_to_sink(&mut self.buf);
    }
}

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static RING: RefCell<Ring> = const { RefCell::new(Ring { buf: Vec::new() }) };
}

/// Microseconds since the process trace epoch (first call wins).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn flush_to_sink(buf: &mut Vec<Event>) {
    if buf.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        sink.append(buf);
    }
}

fn push_event(ev: Event) {
    let _ = RING.try_with(|r| {
        let mut r = r.borrow_mut();
        r.buf.push(ev);
        if r.buf.len() >= RING_CAPACITY {
            flush_to_sink(&mut r.buf);
        }
    });
}

struct SpanData {
    name: Cow<'static, str>,
    cat: &'static str,
    start_us: u64,
    job: Option<String>,
}

/// RAII span: records one `Event` on drop.  `None` inside = disabled.
pub struct Span {
    data: Option<SpanData>,
}

impl Span {
    /// A span that records nothing (the disabled fast path).
    pub const fn disabled() -> Span {
        Span { data: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(d) = self.data.take() else { return };
        let end = now_us();
        push_event(Event {
            name: d.name,
            cat: Cow::Borrowed(d.cat),
            ts_us: d.start_us,
            dur_us: end.saturating_sub(d.start_us),
            pid: std::process::id(),
            tid: TID.with(|t| *t),
            arg_job: d.job,
        });
    }
}

/// Open a span with static category + name.  One relaxed atomic load and
/// zero allocation when tracing is disabled.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !super::enabled() {
        return Span::disabled();
    }
    Span {
        data: Some(SpanData {
            name: Cow::Borrowed(name),
            cat,
            start_us: now_us(),
            job: None,
        }),
    }
}

/// Open a span whose label is computed only when tracing is enabled —
/// call sites pay for `format!` exclusively on the traced path.
#[inline]
pub fn span_with<F>(cat: &'static str, make: F) -> Span
where
    F: FnOnce() -> (String, Option<String>),
{
    if !super::enabled() {
        return Span::disabled();
    }
    let (name, job) = make();
    Span {
        data: Some(SpanData {
            name: Cow::Owned(name),
            cat,
            start_us: now_us(),
            job,
        }),
    }
}

/// Drain everything collected so far: the calling thread's ring plus the
/// global sink.  Other live threads' partial rings are not visible —
/// callers drain after joining their workers.
pub fn take_events() -> Vec<Event> {
    let _ = RING.try_with(|r| flush_to_sink(&mut r.borrow_mut().buf));
    match SINK.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

fn event_json(ev: &Event) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(ev.name.to_string()));
    m.insert("cat".to_string(), Json::Str(ev.cat.to_string()));
    m.insert("ph".to_string(), Json::Str("X".to_string()));
    m.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
    m.insert("dur".to_string(), Json::Num(ev.dur_us as f64));
    m.insert("pid".to_string(), Json::Num(ev.pid as f64));
    m.insert("tid".to_string(), Json::Num(ev.tid as f64));
    if let Some(job) = &ev.arg_job {
        let mut args = BTreeMap::new();
        args.insert("job".to_string(), Json::Str(job.clone()));
        m.insert("args".to_string(), Json::Obj(args));
    }
    Json::Obj(m)
}

fn event_from_json(j: &Json) -> Option<Event> {
    Some(Event {
        name: Cow::Owned(j.get("name")?.as_str()?.to_string()),
        cat: Cow::Owned(j.get("cat")?.as_str()?.to_string()),
        ts_us: j.get("ts")?.as_f64()? as u64,
        dur_us: j.get("dur")?.as_f64()? as u64,
        pid: j.get("pid")?.as_f64()? as u32,
        tid: j.get("tid")?.as_f64()? as u64,
        arg_job: j
            .get("args")
            .and_then(|a| a.get("job"))
            .and_then(|s| s.as_str())
            .map(|s| s.to_string()),
    })
}

/// One timeseries sample as a Chrome counter event (`"ph":"C"`): the
/// track group becomes the counter name, the series the `args` key, so
/// same-group samples stack into one lane.
fn counter_event_json(s: &CounterSample) -> Json {
    let (name, series) = s.name_series();
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("cat".to_string(), Json::Str("counter".to_string()));
    m.insert("ph".to_string(), Json::Str("C".to_string()));
    m.insert("ts".to_string(), Json::Num(s.ts_us as f64));
    m.insert("pid".to_string(), Json::Num(s.pid as f64));
    m.insert("tid".to_string(), Json::Num(0.0));
    let mut args = BTreeMap::new();
    args.insert(series.to_string(), Json::Num(s.value));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

/// Render spans + counter samples as a Chrome trace-event JSON document.
pub fn chrome_trace_json(events: &[Event], samples: &[CounterSample]) -> Json {
    let mut root = BTreeMap::new();
    let mut arr: Vec<Json> = events.iter().map(event_json).collect();
    arr.extend(samples.iter().map(counter_event_json));
    root.insert("traceEvents".to_string(), Json::Arr(arr));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Drain all collected spans *and* timeseries samples and write them as
/// one Chrome trace to `path`.  Returns the number of trace events
/// written (spans + counter samples).
pub fn write_chrome_trace(path: &Path) -> anyhow::Result<usize> {
    write_chrome_trace_with(path, &super::timeseries::take_samples())
}

/// As [`write_chrome_trace`], but with the counter samples supplied by
/// the caller (who may have drained them already for `timeseries.json`).
pub fn write_chrome_trace_with(path: &Path, samples: &[CounterSample]) -> anyhow::Result<usize> {
    let events = take_events();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace_json(&events, samples).to_string())?;
    Ok(events.len() + samples.len())
}

/// Render a worker-side span batch as one protocol line:
/// `{"hash":"…","spans":[…]}`.
pub fn render_span_batch(hash: &str, events: &[Event]) -> String {
    render_flight_batch(hash, events, &[], &[])
}

/// Render the full flight-recorder batch — spans, counter samples, and
/// adaptation events — as one protocol line.  The `"spans"` key is
/// always present (it is the batch marker the orchestrator keys on).
pub fn render_flight_batch(
    hash: &str,
    events: &[Event],
    samples: &[CounterSample],
    adapt: &[AdaptEvent],
) -> String {
    let mut m = BTreeMap::new();
    m.insert("hash".to_string(), Json::Str(hash.to_string()));
    m.insert(
        "spans".to_string(),
        Json::Arr(events.iter().map(event_json).collect()),
    );
    if !samples.is_empty() {
        m.insert(
            "counters".to_string(),
            Json::Arr(samples.iter().map(super::timeseries::sample_json).collect()),
        );
    }
    if !adapt.is_empty() {
        m.insert(
            "events".to_string(),
            Json::Arr(adapt.iter().map(super::events::event_json).collect()),
        );
    }
    Json::Obj(m).to_string()
}

/// Parse a span batch back into `(job hash, events)`.  Returns `None`
/// when `j` is not a batch (no `"spans"` key).
pub fn parse_span_batch(j: &Json) -> Option<(String, Vec<Event>)> {
    let spans = j.get("spans")?.as_arr()?;
    let hash = j.get("hash").and_then(|h| h.as_str()).unwrap_or("");
    Some((
        hash.to_string(),
        spans.iter().filter_map(event_from_json).collect(),
    ))
}

/// Merge a worker flight-recorder batch into the host timeline.  Worker
/// spans keep their own pid/tid lanes; all three streams (spans, counter
/// samples, adaptation events) are shifted by one common delta so the
/// batch's latest timestamp coincides with the host-side receive time,
/// and items missing a job arg inherit the batch's job hash.  Returns
/// how many items were absorbed.  An all-empty batch is a no-op.
pub fn absorb_remote_batch(j: &Json) -> usize {
    let Some((hash, mut events)) = parse_span_batch(j) else {
        return 0;
    };
    let mut samples: Vec<CounterSample> = j
        .get("counters")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(super::timeseries::sample_from_json)
                .collect()
        })
        .unwrap_or_default();
    let mut adapt: Vec<AdaptEvent> = j
        .get("events")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(super::events::event_from_json)
                .collect()
        })
        .unwrap_or_default();
    let max_end = events
        .iter()
        .map(|e| e.ts_us + e.dur_us)
        .chain(samples.iter().map(|s| s.ts_us))
        .chain(adapt.iter().map(|a| a.ts_us))
        .max();
    let Some(max_end) = max_end else {
        return 0; // nothing in the batch
    };
    let now = now_us();
    let shift = |ts: u64| (ts + now).saturating_sub(max_end);
    for e in &mut events {
        e.ts_us = shift(e.ts_us);
        if e.arg_job.is_none() && !hash.is_empty() {
            e.arg_job = Some(hash.clone());
        }
    }
    for s in &mut samples {
        s.ts_us = shift(s.ts_us);
    }
    for a in &mut adapt {
        a.ts_us = shift(a.ts_us);
        if a.arg_job.is_none() && !hash.is_empty() {
            a.arg_job = Some(hash.clone());
        }
    }
    let n = events.len() + samples.len() + adapt.len();
    if let Ok(mut sink) = SINK.lock() {
        sink.append(&mut events);
    }
    super::timeseries::absorb(samples);
    super::events::absorb(adapt);
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_events();
        for _ in 0..100 {
            let _sp = span("disabled-test", "noop");
        }
        let sp = span_with("disabled-test", || ("never".to_string(), None));
        drop(sp);
        assert!(take_events().iter().all(|e| e.cat != "disabled-test"));
    }

    #[test]
    fn spans_nest_and_interleave_per_thread() {
        let _g = crate::obs::test_guard();
        let _ = take_events();
        crate::obs::set_enabled(true);
        const THREADS: usize = 4;
        const REPS: usize = 50;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..REPS {
                        let _outer = span("nest-test", "outer");
                        let _inner = span("nest-test", "inner");
                    }
                });
            }
        });
        crate::obs::set_enabled(false);
        let events: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.cat == "nest-test")
            .collect();
        assert_eq!(events.len(), THREADS * REPS * 2);
        let mut by_tid: BTreeMap<u64, Vec<&Event>> = BTreeMap::new();
        for e in &events {
            by_tid.entry(e.tid).or_default().push(e);
        }
        assert_eq!(by_tid.len(), THREADS, "one timeline lane per thread");
        for evs in by_tid.values() {
            let outers: Vec<&&Event> = evs.iter().filter(|e| e.name == "outer").collect();
            let inners: Vec<&&Event> = evs.iter().filter(|e| e.name == "inner").collect();
            assert_eq!(outers.len(), REPS);
            assert_eq!(inners.len(), REPS);
            // every inner interval must lie within an outer interval on
            // its own thread — the nesting invariant Perfetto renders
            for i in &inners {
                assert!(
                    outers.iter().any(|o| o.ts_us <= i.ts_us
                        && i.ts_us + i.dur_us <= o.ts_us + o.dur_us),
                    "inner span must nest inside an outer on its thread"
                );
            }
        }
    }

    #[test]
    fn chrome_trace_json_shape() {
        let events = vec![Event {
            name: Cow::Borrowed("encode"),
            cat: Cow::Borrowed("stash"),
            ts_us: 5,
            dur_us: 17,
            pid: 1,
            tid: 2,
            arg_job: Some("cafe0123".to_string()),
        }];
        let samples = vec![
            CounterSample {
                track: Cow::Borrowed("stash_bytes.resident"),
                ts_us: 6,
                value: 4096.0,
                pid: 1,
            },
            CounterSample {
                track: Cow::Borrowed("stash_queue_depth"),
                ts_us: 7,
                value: 3.0,
                pid: 1,
            },
        ];
        let doc = chrome_trace_json(&events, &samples);
        assert_eq!(
            doc.get("displayTimeUnit").and_then(Json::as_str),
            Some("ms")
        );
        let ev = doc.get("traceEvents").unwrap().idx(0).unwrap();
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(5.0));
        assert_eq!(ev.get("dur").and_then(Json::as_f64), Some(17.0));
        assert_eq!(
            ev.get("args").and_then(|a| a.get("job")).and_then(Json::as_str),
            Some("cafe0123")
        );
        // counter samples render as ph:"C" tracks: group -> name,
        // series -> args key (bare names get series "value")
        let c0 = doc.get("traceEvents").unwrap().idx(1).unwrap();
        assert_eq!(c0.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(c0.get("name").and_then(Json::as_str), Some("stash_bytes"));
        assert_eq!(
            c0.get("args").and_then(|a| a.get("resident")).and_then(Json::as_f64),
            Some(4096.0)
        );
        let c1 = doc.get("traceEvents").unwrap().idx(2).unwrap();
        assert_eq!(
            c1.get("name").and_then(Json::as_str),
            Some("stash_queue_depth")
        );
        assert_eq!(
            c1.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64),
            Some(3.0)
        );
    }

    #[test]
    fn span_batch_round_trips_and_merges_into_the_host_timeline() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_events();
        let events = vec![
            Event {
                name: Cow::Borrowed("execute"),
                cat: Cow::Borrowed("remote-test"),
                ts_us: 10,
                dur_us: 80,
                pid: 4242,
                tid: 7,
                arg_job: None,
            },
            Event {
                name: Cow::Borrowed("commit"),
                cat: Cow::Borrowed("remote-test"),
                ts_us: 90,
                dur_us: 10,
                pid: 4242,
                tid: 7,
                arg_job: Some("deadbeef".to_string()),
            },
        ];
        let line = render_span_batch("deadbeef", &events);
        assert!(!line.contains('\n'), "one batch = one protocol line");
        let j = Json::parse(&line).unwrap();
        let (hash, parsed) = parse_span_batch(&j).unwrap();
        assert_eq!(hash, "deadbeef");
        assert_eq!(parsed, events);
        // a response line is not a batch
        assert!(parse_span_batch(&Json::parse(r#"{"hash":"x","ok":true}"#).unwrap()).is_none());

        assert_eq!(absorb_remote_batch(&j), 2);
        let merged: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.cat == "remote-test")
            .collect();
        assert_eq!(merged.len(), 2);
        // worker identity survives the merge; the batch hash keys every span
        assert!(merged.iter().all(|e| e.pid == 4242));
        assert!(merged
            .iter()
            .all(|e| e.arg_job.as_deref() == Some("deadbeef")));
        // shifted so the batch's latest end is at/before host receive time
        let max_end = merged.iter().map(|e| e.ts_us + e.dur_us).max().unwrap();
        assert!(max_end <= now_us());
        // relative spacing within the batch is preserved
        let a = merged.iter().find(|e| e.name == "execute").unwrap();
        let b = merged.iter().find(|e| e.name == "commit").unwrap();
        assert_eq!(b.ts_us - a.ts_us, 80);
        assert_eq!(a.dur_us, 80);
    }

    #[test]
    fn empty_and_interleaved_batches_merge_cleanly() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_events();
        let _ = super::super::events::take_events();
        let _ = super::super::timeseries::take_samples();
        // an all-empty batch (worker had nothing to report) is a no-op
        let empty = Json::parse(r#"{"hash":"aaaa","spans":[]}"#).unwrap();
        assert_eq!(absorb_remote_batch(&empty), 0);
        assert!(take_events().is_empty());
        // interleave batches from two workers, out of order, including a
        // spans-empty batch that still carries counters + adapt events
        let mk_span = |name: &'static str, pid: u32| Event {
            name: Cow::Borrowed(name),
            cat: Cow::Borrowed("interleave-test"),
            ts_us: 10,
            dur_us: 5,
            pid,
            tid: 1,
            arg_job: None,
        };
        let w1a = render_span_batch("1111", &[mk_span("j1.execute", 100)]);
        let samples = vec![CounterSample {
            track: Cow::Borrowed("stash_bytes.resident"),
            ts_us: 20,
            value: 512.0,
            pid: 200,
        }];
        let adapt = vec![AdaptEvent {
            ts_us: 21,
            pid: 200,
            kind: Cow::Borrowed("bitlength"),
            source: Cow::Borrowed("qm"),
            trigger: Cow::Borrowed("qm_gradient_step"),
            layer: Some(0),
            tensor_class: Some(Cow::Borrowed("act")),
            component: Some(Cow::Borrowed("mant")),
            epoch: Some(0),
            step: Some(1),
            from: 8.0,
            to: 7.0,
            detail: None,
            arg_job: None,
            owner: None,
        }];
        let w2 = render_flight_batch("2222", &[], &samples, &adapt);
        let w1b = render_span_batch("1111", &[mk_span("j1.commit", 100)]);
        for line in [&w2, &w1a, &w1b] {
            let n = absorb_remote_batch(&Json::parse(line).unwrap());
            assert!(n >= 1, "every non-empty batch absorbs something");
        }
        let spans: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.cat == "interleave-test")
            .collect();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|e| e.arg_job.as_deref() == Some("1111")));
        let merged_samples = super::super::timeseries::take_samples();
        assert_eq!(merged_samples.len(), 1);
        assert_eq!(merged_samples[0].track, "stash_bytes.resident");
        // filter: the adapt sink is always-on and unguarded tests may
        // push concurrently — key on this test's batch hash
        let merged_adapt: Vec<AdaptEvent> = super::super::events::take_events()
            .into_iter()
            .filter(|a| a.arg_job.as_deref() == Some("2222"))
            .collect();
        assert_eq!(merged_adapt.len(), 1);
        assert!(merged_adapt[0].ts_us <= now_us());
    }
}
