//! Span/event tracing: RAII spans into thread-local rings, a global
//! collector, Chrome trace-event export, and cross-process batch merge.
//!
//! A [`Span`] is recorded on drop as one complete event (`"ph":"X"`).
//! When tracing is disabled ([`crate::obs::enabled`] is false) a span is
//! a `None` — constructing and dropping it performs no allocation and no
//! clock read.  Enabled, events land in a per-thread ring that flushes
//! to the global sink every [`RING_CAPACITY`] events and on thread exit,
//! so hot paths never contend on the sink lock.
//!
//! Timestamps are microseconds from a process-wide monotonic epoch
//! (first use), which keeps them positive, small, and Perfetto-friendly.
//! Worker processes have their own epoch; [`absorb_remote_batch`] shifts
//! a worker batch so its latest span end lands at the host-side receive
//! time, which is the best alignment available without a shared clock.

use crate::util::json::Json;
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Events buffered per thread before a flush to the global sink.
pub const RING_CAPACITY: usize = 128;

/// One completed span, shaped for the Chrome trace-event format.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: Cow<'static, str>,
    pub cat: Cow<'static, str>,
    /// Start, µs since the process trace epoch.
    pub ts_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    pub pid: u32,
    pub tid: u64,
    /// Job content hash, when the span belongs to a lab job.
    pub arg_job: Option<String>,
}

static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

struct Ring {
    buf: Vec<Event>,
}

impl Drop for Ring {
    fn drop(&mut self) {
        flush_to_sink(&mut self.buf);
    }
}

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static RING: RefCell<Ring> = const { RefCell::new(Ring { buf: Vec::new() }) };
}

/// Microseconds since the process trace epoch (first call wins).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn flush_to_sink(buf: &mut Vec<Event>) {
    if buf.is_empty() {
        return;
    }
    if let Ok(mut sink) = SINK.lock() {
        sink.append(buf);
    }
}

fn push_event(ev: Event) {
    let _ = RING.try_with(|r| {
        let mut r = r.borrow_mut();
        r.buf.push(ev);
        if r.buf.len() >= RING_CAPACITY {
            flush_to_sink(&mut r.buf);
        }
    });
}

struct SpanData {
    name: Cow<'static, str>,
    cat: &'static str,
    start_us: u64,
    job: Option<String>,
}

/// RAII span: records one `Event` on drop.  `None` inside = disabled.
pub struct Span {
    data: Option<SpanData>,
}

impl Span {
    /// A span that records nothing (the disabled fast path).
    pub const fn disabled() -> Span {
        Span { data: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(d) = self.data.take() else { return };
        let end = now_us();
        push_event(Event {
            name: d.name,
            cat: Cow::Borrowed(d.cat),
            ts_us: d.start_us,
            dur_us: end.saturating_sub(d.start_us),
            pid: std::process::id(),
            tid: TID.with(|t| *t),
            arg_job: d.job,
        });
    }
}

/// Open a span with static category + name.  One relaxed atomic load and
/// zero allocation when tracing is disabled.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !super::enabled() {
        return Span::disabled();
    }
    Span {
        data: Some(SpanData {
            name: Cow::Borrowed(name),
            cat,
            start_us: now_us(),
            job: None,
        }),
    }
}

/// Open a span whose label is computed only when tracing is enabled —
/// call sites pay for `format!` exclusively on the traced path.
#[inline]
pub fn span_with<F>(cat: &'static str, make: F) -> Span
where
    F: FnOnce() -> (String, Option<String>),
{
    if !super::enabled() {
        return Span::disabled();
    }
    let (name, job) = make();
    Span {
        data: Some(SpanData {
            name: Cow::Owned(name),
            cat,
            start_us: now_us(),
            job,
        }),
    }
}

/// Drain everything collected so far: the calling thread's ring plus the
/// global sink.  Other live threads' partial rings are not visible —
/// callers drain after joining their workers.
pub fn take_events() -> Vec<Event> {
    let _ = RING.try_with(|r| flush_to_sink(&mut r.borrow_mut().buf));
    match SINK.lock() {
        Ok(mut sink) => std::mem::take(&mut *sink),
        Err(_) => Vec::new(),
    }
}

fn event_json(ev: &Event) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(ev.name.to_string()));
    m.insert("cat".to_string(), Json::Str(ev.cat.to_string()));
    m.insert("ph".to_string(), Json::Str("X".to_string()));
    m.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
    m.insert("dur".to_string(), Json::Num(ev.dur_us as f64));
    m.insert("pid".to_string(), Json::Num(ev.pid as f64));
    m.insert("tid".to_string(), Json::Num(ev.tid as f64));
    if let Some(job) = &ev.arg_job {
        let mut args = BTreeMap::new();
        args.insert("job".to_string(), Json::Str(job.clone()));
        m.insert("args".to_string(), Json::Obj(args));
    }
    Json::Obj(m)
}

fn event_from_json(j: &Json) -> Option<Event> {
    Some(Event {
        name: Cow::Owned(j.get("name")?.as_str()?.to_string()),
        cat: Cow::Owned(j.get("cat")?.as_str()?.to_string()),
        ts_us: j.get("ts")?.as_f64()? as u64,
        dur_us: j.get("dur")?.as_f64()? as u64,
        pid: j.get("pid")?.as_f64()? as u32,
        tid: j.get("tid")?.as_f64()? as u64,
        arg_job: j
            .get("args")
            .and_then(|a| a.get("job"))
            .and_then(|s| s.as_str())
            .map(|s| s.to_string()),
    })
}

/// Render events as a Chrome trace-event JSON document.
pub fn chrome_trace_json(events: &[Event]) -> Json {
    let mut root = BTreeMap::new();
    root.insert(
        "traceEvents".to_string(),
        Json::Arr(events.iter().map(event_json).collect()),
    );
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Drain all collected events and write them as a Chrome trace to `path`.
/// Returns the number of events written.
pub fn write_chrome_trace(path: &Path) -> anyhow::Result<usize> {
    let events = take_events();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace_json(&events).to_string())?;
    Ok(events.len())
}

/// Render a worker-side span batch as one protocol line:
/// `{"hash":"…","spans":[…]}`.
pub fn render_span_batch(hash: &str, events: &[Event]) -> String {
    let mut m = BTreeMap::new();
    m.insert("hash".to_string(), Json::Str(hash.to_string()));
    m.insert(
        "spans".to_string(),
        Json::Arr(events.iter().map(event_json).collect()),
    );
    Json::Obj(m).to_string()
}

/// Parse a span batch back into `(job hash, events)`.  Returns `None`
/// when `j` is not a batch (no `"spans"` key).
pub fn parse_span_batch(j: &Json) -> Option<(String, Vec<Event>)> {
    let spans = j.get("spans")?.as_arr()?;
    let hash = j.get("hash").and_then(|h| h.as_str()).unwrap_or("");
    Some((
        hash.to_string(),
        spans.iter().filter_map(event_from_json).collect(),
    ))
}

/// Merge a worker span batch into the host timeline.  Worker events keep
/// their own pid/tid lanes; timestamps are shifted so the batch's latest
/// span end coincides with the host-side receive time, and spans missing
/// a job arg inherit the batch's job hash.  Returns how many events were
/// absorbed.
pub fn absorb_remote_batch(j: &Json) -> usize {
    let Some((hash, mut events)) = parse_span_batch(j) else {
        return 0;
    };
    if events.is_empty() {
        return 0;
    }
    let max_end = events
        .iter()
        .map(|e| e.ts_us + e.dur_us)
        .max()
        .unwrap_or(0);
    let now = now_us();
    for e in &mut events {
        e.ts_us = (e.ts_us + now).saturating_sub(max_end);
        if e.arg_job.is_none() && !hash.is_empty() {
            e.arg_job = Some(hash.clone());
        }
    }
    let n = events.len();
    if let Ok(mut sink) = SINK.lock() {
        sink.append(&mut events);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_events();
        for _ in 0..100 {
            let _sp = span("disabled-test", "noop");
        }
        let sp = span_with("disabled-test", || ("never".to_string(), None));
        drop(sp);
        assert!(take_events().iter().all(|e| e.cat != "disabled-test"));
    }

    #[test]
    fn spans_nest_and_interleave_per_thread() {
        let _g = crate::obs::test_guard();
        let _ = take_events();
        crate::obs::set_enabled(true);
        const THREADS: usize = 4;
        const REPS: usize = 50;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..REPS {
                        let _outer = span("nest-test", "outer");
                        let _inner = span("nest-test", "inner");
                    }
                });
            }
        });
        crate::obs::set_enabled(false);
        let events: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.cat == "nest-test")
            .collect();
        assert_eq!(events.len(), THREADS * REPS * 2);
        let mut by_tid: BTreeMap<u64, Vec<&Event>> = BTreeMap::new();
        for e in &events {
            by_tid.entry(e.tid).or_default().push(e);
        }
        assert_eq!(by_tid.len(), THREADS, "one timeline lane per thread");
        for evs in by_tid.values() {
            let outers: Vec<&&Event> = evs.iter().filter(|e| e.name == "outer").collect();
            let inners: Vec<&&Event> = evs.iter().filter(|e| e.name == "inner").collect();
            assert_eq!(outers.len(), REPS);
            assert_eq!(inners.len(), REPS);
            // every inner interval must lie within an outer interval on
            // its own thread — the nesting invariant Perfetto renders
            for i in &inners {
                assert!(
                    outers.iter().any(|o| o.ts_us <= i.ts_us
                        && i.ts_us + i.dur_us <= o.ts_us + o.dur_us),
                    "inner span must nest inside an outer on its thread"
                );
            }
        }
    }

    #[test]
    fn chrome_trace_json_shape() {
        let events = vec![Event {
            name: Cow::Borrowed("encode"),
            cat: Cow::Borrowed("stash"),
            ts_us: 5,
            dur_us: 17,
            pid: 1,
            tid: 2,
            arg_job: Some("cafe0123".to_string()),
        }];
        let doc = chrome_trace_json(&events);
        assert_eq!(
            doc.get("displayTimeUnit").and_then(Json::as_str),
            Some("ms")
        );
        let ev = doc.get("traceEvents").unwrap().idx(0).unwrap();
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(ev.get("ts").and_then(Json::as_f64), Some(5.0));
        assert_eq!(ev.get("dur").and_then(Json::as_f64), Some(17.0));
        assert_eq!(
            ev.get("args").and_then(|a| a.get("job")).and_then(Json::as_str),
            Some("cafe0123")
        );
    }

    #[test]
    fn span_batch_round_trips_and_merges_into_the_host_timeline() {
        let _g = crate::obs::test_guard();
        crate::obs::set_enabled(false);
        let _ = take_events();
        let events = vec![
            Event {
                name: Cow::Borrowed("execute"),
                cat: Cow::Borrowed("remote-test"),
                ts_us: 10,
                dur_us: 80,
                pid: 4242,
                tid: 7,
                arg_job: None,
            },
            Event {
                name: Cow::Borrowed("commit"),
                cat: Cow::Borrowed("remote-test"),
                ts_us: 90,
                dur_us: 10,
                pid: 4242,
                tid: 7,
                arg_job: Some("deadbeef".to_string()),
            },
        ];
        let line = render_span_batch("deadbeef", &events);
        assert!(!line.contains('\n'), "one batch = one protocol line");
        let j = Json::parse(&line).unwrap();
        let (hash, parsed) = parse_span_batch(&j).unwrap();
        assert_eq!(hash, "deadbeef");
        assert_eq!(parsed, events);
        // a response line is not a batch
        assert!(parse_span_batch(&Json::parse(r#"{"hash":"x","ok":true}"#).unwrap()).is_none());

        assert_eq!(absorb_remote_batch(&j), 2);
        let merged: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.cat == "remote-test")
            .collect();
        assert_eq!(merged.len(), 2);
        // worker identity survives the merge; the batch hash keys every span
        assert!(merged.iter().all(|e| e.pid == 4242));
        assert!(merged
            .iter()
            .all(|e| e.arg_job.as_deref() == Some("deadbeef")));
        // shifted so the batch's latest end is at/before host receive time
        let max_end = merged.iter().map(|e| e.ts_us + e.dur_us).max().unwrap();
        assert!(max_end <= now_us());
        // relative spacing within the batch is preserved
        let a = merged.iter().find(|e| e.name == "execute").unwrap();
        let b = merged.iter().find(|e| e.name == "commit").unwrap();
        assert_eq!(b.ts_us - a.ts_us, 80);
        assert_eq!(a.dur_us, 80);
    }
}
